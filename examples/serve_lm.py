"""Batched serving example: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --new 16

Exercises the same prefill/decode_step closures the dry-run's decode
shapes lower (ring-buffer KV for SWA archs, O(1) recurrent state for
SSM/hybrid).
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.api import build_model
from repro.serve.decode import greedy_generate
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_frames, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.perf_counter()
    toks = greedy_generate(model, params, batch, max_new=args.new,
                           max_len=args.prompt_len + args.new)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: generated {args.new} tokens x {args.batch} seqs "
          f"in {dt:.1f}s ({args.batch*args.new/dt:.1f} tok/s incl. compile)")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
