"""End-to-end LM training driver (example b: train a model for a few
hundred steps on the synthetic pipeline).

    PYTHONPATH=src python examples/train_lm.py --preset demo    # CPU, ~5 min
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # real hardware

``demo`` is a ~6M-param qwen3-family model sized for this single-CPU
container; ``100m`` is the ~100M-param config the assignment describes and
uses the identical code path (swap of ArchConfig only) — on a TPU slice
the launch layer shards it with launch/sharding.py.
"""
import argparse

import jax

from repro.configs.base import ArchConfig
from repro.data import DataSpec, SyntheticLM
from repro.models.api import build_model
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer

PRESETS = {
    "demo": ArchConfig(
        name="qwen3-demo-6m", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=384, vocab=4096, qk_norm=True, tie_embeddings=True,
    ),
    "100m": ArchConfig(
        name="qwen3-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=32768, qk_norm=True, tie_embeddings=True,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch))
    opt = AdamW(lr=6e-4, warmup_steps=args.steps // 20,
                total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                     ckpt_dir=args.ckpt_dir)
    trainer = Trainer(model, opt, tc)
    _, _, losses = trainer.run(jax.random.PRNGKey(0), data)
    k = max(args.steps // 10, 1)
    print(f"loss: {sum(losses[:k])/k:.3f} -> {sum(losses[-k:])/k:.3f} "
          f"(first/last {k}-step mean)")


if __name__ == "__main__":
    main()
