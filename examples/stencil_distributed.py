"""L2 SO2DR: the paper's redundant-compute trade at the inter-chip level.

Runs the shard_map ghost-cell-expansion stencil on 8 placeholder devices,
sweeping k_ici and printing the collective-phase/byte trade (DESIGN.md §2).

    PYTHONPATH=src python examples/stencil_distributed.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax.numpy as jnp

from repro.compat import AxisType, make_mesh
from repro.core.distributed import collective_bytes_per_round, run_distributed
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil


def main():
    mesh = make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 256)).astype(np.float32)
    n = 8
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    local = (x.shape[0] // 4, x.shape[1] // 2)

    print(f"domain {x.shape} on mesh {dict(mesh.shape)} — {n} steps\n")
    for k in (1, 2, 4, 8):
        out = np.asarray(run_distributed(jnp.asarray(x), st.name, n, k, mesh))
        err = np.abs(out - ref).max()
        by = collective_bytes_per_round(local, st.radius, k, 4)
        print(f"k_ici={k}:  max_err={err:.2e}  exchanges/step={4/k:.2f}  "
              f"ICI bytes/step/rank={by/k:,.0f}")
    print("\nk_ici trades a tiny byte overhead (corner term) for k x fewer "
          "collective phases — SO2DR's trade, one level up.")


if __name__ == "__main__":
    main()
