"""Quickstart: the paper's technique, plan/execute style, in ~50 lines.

Each engine *compiles* its schedule into a typed transfer/kernel op plan;
pluggable executors then interpret the same plan: a zero-device dry run
(exact accounting), the eager interpreter, and the double-buffered one
(chunk i+1's H2D prefetched under chunk i's kernels — the paper's
multi-stream overlap).  All three agree with the oracle / each other.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.analytic import TPU_V5E, times_from_plan
from repro.core.executor import DoubleBufferedExecutor, DryRunExecutor, EagerExecutor
from repro.core.oocore import ResReu, SO2DR
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil


def main():
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((514, 514)).astype(np.float32)
    n, d, k_off, k_on = 32, 4, 16, 4

    print(f"domain {x.shape}, {n} steps, d={d} chunks, "
          f"k_off={k_off}, k_on={k_on}\n")

    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    for eng in (SO2DR(d=d, k_off=k_off, k_on=k_on),
                ResReu(d=d, k_off=k_off, k_on=k_on)):
        # 1. compile: geometry -> op schedule (no arrays touched)
        plan = eng.compile(x.shape[0], x.shape[1], st, n, itemsize=x.itemsize)
        # 2. dry run: exact accounting straight off the plan
        _, stats = DryRunExecutor().execute(plan)
        # 3. execute: eager and double-buffered walk the same lowered
        #    stage programs (see repro.core.lower)
        ex = EagerExecutor()
        out, _ = ex.execute(plan, x)
        out_db, _ = DoubleBufferedExecutor().execute(plan, x)
        assert np.array_equal(out, out_db), "pipelining must not change results"
        err = np.abs(out - ref).max() / np.abs(ref).max()
        t = times_from_plan(plan, TPU_V5E)
        ops = plan.op_counts()
        es = ex.exec_stats
        print(f"{eng.name:8s} max_rel_err={err:.2e}  "
              f"plan={len(plan)} ops ({ops.get('FusedKernel', 0)} kernels, "
              f"{es.kernel_compiles} compiled via {es.shape_buckets} shape "
              f"buckets)  "
              f"h2d={stats.h2d_bytes/1e6:.1f}MB  "
              f"redundant={stats.redundancy*100:.1f}%  "
              f"kernel_phase={t.kernel*1e6:.0f}us  "
              f"modeled_tpu_total={t.total_overlapped()*1e3:.2f}ms")
    print("\nSO2DR: same transfer volume, ~k_on x fewer kernel launches and a "
          "shorter kernel phase\n(on-chip reuse); at this toy size both "
          "engines are transfer-bound — benchmarks/fig6\nruns the paper's "
          "11 GB workload where the kernel phase decides the total.")


if __name__ == "__main__":
    main()
