"""Quickstart: the paper's technique in 40 lines.

Runs SO2DR (region sharing + redundant compute + fused k_on-step Pallas
kernels) against ResReu and the oracle on a small out-of-core workload,
printing the accounting that drives the paper's Fig. 6/7.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.analytic import TPU_V5E, model_times
from repro.core.oocore import ResReu, SO2DR
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil


def main():
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((514, 514)).astype(np.float32)
    n, d, k_off, k_on = 32, 4, 16, 4

    print(f"domain {x.shape}, {n} steps, d={d} chunks, "
          f"k_off={k_off}, k_on={k_on}\n")

    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    for eng in (SO2DR(d=d, k_off=k_off, k_on=k_on),
                ResReu(d=d, k_off=k_off, k_on=k_on)):
        out, stats = eng.run(x, st, n)
        err = np.abs(out - ref).max() / np.abs(ref).max()
        t = model_times(stats, TPU_V5E)
        print(f"{eng.name:8s} max_rel_err={err:.2e}  "
              f"h2d={stats.h2d_bytes/1e6:.1f}MB  "
              f"kernel_calls={stats.kernel_calls:4d}  "
              f"redundant={stats.redundancy*100:.1f}%  "
              f"kernel_phase={t.kernel*1e6:.0f}us  "
              f"modeled_tpu_total={t.total_overlapped()*1e3:.2f}ms")
    print("\nSO2DR: same transfer volume, ~k_on x fewer kernel launches and a "
          "shorter kernel phase\n(on-chip reuse); at this toy size both "
          "engines are transfer-bound — benchmarks/fig6\nruns the paper's "
          "11 GB workload where the kernel phase decides the total.")


if __name__ == "__main__":
    main()
