"""Stencil-as-a-service: warm caches + cross-job pipelining in ~60 lines.

A long-lived :class:`StencilService` amortizes kernel compilation across
jobs (one warm KernelCache + cross-job shape-bucket registry + device
slot pool) and interleaves concurrent jobs' stage programs so one job's
H2D hides under another job's kernels — overlap a single job's schedule
can never express.

    PYTHONPATH=src python examples/serve_stencil.py
"""
import numpy as np

from repro.kernels.dispatch import DispatchPolicy
from repro.serve import StencilJob, StencilService


def main():
    # one policy for the service lifetime keeps kernel signatures stable
    svc = StencilService(policy=DispatchPolicy(impl="reference"))
    rng = np.random.default_rng(7)

    batch = [
        StencilJob(shape=(130, 130), stencil="box2d1r", steps=16,
                   d=4, s_tb=4, deadline=0.5),
        StencilJob(shape=(130, 130), stencil="gradient2d", steps=16,
                   d=4, s_tb=4),
        StencilJob(shape=(106, 130), stencil="box2d1r", steps=16,
                   d=4, s_tb=4, codec="zrle"),
        StencilJob(shape=(132, 132), stencil="box2d2r", steps=16,
                   d=4, s_tb=4),
    ]
    xs = [rng.standard_normal(j.shape).astype(np.float32) for j in batch]

    print("cold batch (mixed shapes/stencils/codecs):")
    for job, x in zip(batch, xs):
        svc.submit(job, x)
    for r in svc.flush():
        print(f"  job {r.job_id}: latency={r.latency_s*1e3:7.1f}ms  "
              f"predicted={r.predicted_s*1e6:6.1f}us(model)  "
              f"compiles={r.exec_stats.kernel_compiles}  "
              f"cache_hits={r.exec_stats.kernel_cache_hits}")

    mi = svc.modeled_makespan(interleaved=True)
    mb = svc.modeled_makespan(interleaved=False)
    print(f"  modeled makespan: interleaved {mi*1e6:.1f}us vs "
          f"back-to-back {mb*1e6:.1f}us  ({(1 - mi/mb)*100:.0f}% win)")

    # warm resubmits: same buckets -> zero new kernel traces, even for a
    # Y the service has never seen (106 < 130 falls in the 130-bucket)
    print("warm batch (unseen 114-row shape reuses the existing bucket):")
    for job in (batch[0],
                StencilJob(shape=(114, 130), stencil="box2d1r", steps=16,
                           d=4, s_tb=4)):
        svc.submit(job, rng.standard_normal(job.shape).astype(np.float32))
    for r in svc.flush():
        print(f"  job {r.job_id}: latency={r.latency_s*1e3:7.1f}ms  "
              f"compiles={r.exec_stats.kernel_compiles}  "
              f"cache_hits={r.exec_stats.kernel_cache_hits}")

    s = svc.service_stats()
    print(f"service lifetime: {s['jobs_completed']} jobs, "
          f"{s['kernel_compiles']} kernel compiles total, "
          f"{s['kernel_cache_hits']} cache hits, "
          f"{s['shape_buckets']} shape buckets, "
          f"slot pool reuses={s['slot_pool']['reuses']}")


if __name__ == "__main__":
    main()
