"""L2 (ICI) distributed stencil: shard_map + ppermute ghost-cell expansion.

Multi-device correctness runs in a subprocess with 8 fake CPU devices
(via ``tests/_subproc.py``) so the main test session keeps its
single-device jax state (the dry-run is the only place allowed to see
512 devices).
"""
import numpy as np
import jax.numpy as jnp

from _subproc import run_fake_device_subprocess
from repro.compat import AxisType, make_mesh
from repro.core.distributed import (
    collective_bytes_per_round, run_distributed,
)
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil

_SUBPROC = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import AxisType, make_mesh
from repro.core.distributed import run_distributed
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil

mesh = make_mesh((4, 2), ("data", "model"),
                 axis_types=(AxisType.Auto,) * 2)
rng = np.random.default_rng(2)
for name in ("box2d1r", "gradient2d", "box2d2r"):
    st = get_stencil(name)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    for n, k in [(6, 1), (6, 3), (8, 4)]:
        ref = np.asarray(run_reference(jnp.asarray(x), st, n))
        got = np.asarray(run_distributed(jnp.asarray(x), name, n, k, mesh))
        assert np.abs(got - ref).max() < 1e-5, (name, n, k)
print("SUBPROC_OK")
"""


def test_distributed_multidevice_subprocess():
    run_fake_device_subprocess(_SUBPROC, "SUBPROC_OK")


def test_distributed_single_device_mesh():
    """k_ici sweep on a trivial 1x1 mesh (runs in-process)."""
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(5)
    x = rng.standard_normal((32, 32)).astype(np.float32)
    ref = np.asarray(run_reference(jnp.asarray(x), st, 6))
    got = np.asarray(run_distributed(jnp.asarray(x), "box2d1r", 6, 2, mesh))
    assert np.abs(got - ref).max() < 1e-5


def test_collective_overhead_model():
    """Ghost-cell expansion trades a small per-step byte overhead (the
    corner term, O(k*r^2)) for k x fewer collective phases per step — the
    L2 incarnation of the paper's kernel-interruption argument: ResReu's
    cost was per-step interruptions, not bytes."""
    ly, lx, r = 4096, 2048, 1
    per_step = [
        collective_bytes_per_round((ly, lx), r, k, 4) / k for k in (1, 4, 8)
    ]
    # bytes/step grow only by the corner term: (lx+ly+2kr)/(lx+ly+2r)
    assert per_step[2] / per_step[0] < 1.01
    # collective phases per step: 4/k (2 row + 2 col exchanges per round)
    phases = [4 / k for k in (1, 4, 8)]
    assert phases[2] == 0.5 < phases[0]
