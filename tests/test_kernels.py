"""Pallas fused-stencil kernel vs the pure-jnp oracle (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st_h

from repro.kernels.ops import fused_stencil
from repro.kernels.ref import multi_step_band

RNG = np.random.default_rng(7)


def _check(name, H, X, steps, kt, kb, tile=(16, 64), dtype=np.float32, tol=1e-5):
    x = RNG.standard_normal((H, X)).astype(dtype)
    xb = jnp.asarray(x)
    ref = multi_step_band(xb, name, steps, kt, kb)
    got = fused_stencil(xb, name, steps, kt, kb, tile=tile)
    err = np.abs(np.asarray(got, np.float32) - np.asarray(ref, np.float32))
    scale = np.abs(np.asarray(ref, np.float32)).max() + 1e-6
    assert err.max() / scale < tol, (name, H, X, steps, kt, kb, err.max())


@pytest.mark.parametrize("name", ["box2d1r", "box2d2r", "box2d4r", "gradient2d", "star2d3r"])
@pytest.mark.parametrize("steps", [1, 2, 4])
def test_kernel_matches_oracle(name, steps):
    for kt, kb in [(False, False), (True, False), (True, True)]:
        _check(name, 48, 160, steps, kt, kb)


def test_kernel_non_divisible_edges():
    # shapes chosen to exercise clamped DMA starts + padded output tiles
    _check("box2d2r", 37, 131, 2, False, True)
    _check("box2d1r", 41, 97, 4, True, False)


def test_kernel_bf16():
    _check("box2d1r", 64, 256, 4, True, False, dtype=np.float32, tol=1e-5)
    x = RNG.standard_normal((64, 256)).astype(np.float32)
    xb = jnp.asarray(x, jnp.bfloat16)
    ref = multi_step_band(xb, "box2d1r", 4, True, False)
    got = fused_stencil(xb, "box2d1r", 4, True, False, tile=(16, 64))
    err = np.abs(np.asarray(got, np.float32) - np.asarray(ref, np.float32)).max()
    assert err < 3e-2


def test_kernel_tiny_band_fallback():
    # band too small for one apron'd tile -> reference fallback path
    _check("box2d4r", 20, 40, 2, True, True, tile=(256, 512))


@settings(max_examples=15, deadline=None)
@given(
    h=st_h.integers(20, 70),
    x=st_h.integers(30, 150),
    steps=st_h.integers(1, 3),
    r=st_h.sampled_from([1, 2]),
    kt=st_h.booleans(),
    kb=st_h.booleans(),
)
def test_kernel_property(h, x, steps, r, kt, kb):
    name = f"box2d{r}r"
    if h - 2 * steps * r + (kt + kb) * steps * r < 1 or x - 2 * steps * r < 1:
        return
    _check(name, h, x, steps, kt, kb)


def test_banded_mxu_kernel():
    """Beyond-paper MXU-banded kernel (EXPERIMENTS.md §4.3) ≡ oracle."""
    from repro.kernels.stencil_banded_mxu import banded_fused_stencil, mxu_wins
    from repro.core.stencil import get_stencil

    for name in ("box2d1r", "box2d4r"):
        for steps in (1, 2):
            for kt, kb in [(False, False), (True, True)]:
                x = RNG.standard_normal((48, 160)).astype(np.float32)
                ref = multi_step_band(jnp.asarray(x), name, steps, kt, kb)
                got = banded_fused_stencil(jnp.asarray(x), name, steps, kt, kb,
                                           tile=(16, 32))
                err = np.abs(np.asarray(got) - np.asarray(ref)).max()
                assert err < 2e-5, (name, steps, kt, kb, err)
    # the napkin math that motivates it (EXPERIMENTS.md §4.3)
    assert mxu_wins(get_stencil("box2d4r"))
    assert not mxu_wins(get_stencil("box2d4r"), tx=512)


def test_double_buffered_kernel():
    """DMA/compute-overlap variant (DESIGN.md §5) ≡ oracle."""
    from repro.kernels.stencil_multistep_db import fused_stencil_band_db

    for name in ("box2d1r", "gradient2d"):
        for steps in (1, 4):
            for kt, kb in [(False, False), (True, True)]:
                x = RNG.standard_normal((48, 160)).astype(np.float32)
                ref = multi_step_band(jnp.asarray(x), name, steps, kt, kb)
                got = fused_stencil_band_db(jnp.asarray(x), name, steps, kt, kb,
                                            tile=(16, 64))
                err = np.abs(np.asarray(got) - np.asarray(ref)).max()
                assert err < 1e-5, (name, steps, kt, kb, err)
