"""Hierarchical plans: every level of the nesting proven differentially.

The SO2DR recursion — out-of-core streaming nested *inside* each device
shard — must change nothing observable but the traffic pattern:

* the fake-device simulator executing a hierarchical plan is
  bit-identical to the same plan compiled flat, across inner engines
  (so2dr / resreu / box_tb) and outer halo codecs, and matches the
  ``shard_map`` backend and ``run_reference`` to 1e-5 (subprocess, 8
  fake devices) on a mesh whose shards each need >= 3 inner chunks;
* dry-run accounting equals executed accounting at both levels (ICI and
  inner H2D/D2H) field for field;
* property tests (hypothesis, stub-backed on minimal containers): inner
  per-round H2D bytes are exactly the shard subdomain plus the chunk
  aprons, and lossless halo codecs round-trip bit-exactly;
* expansion is a strict no-op when a shard fits: ``compile_hierarchical``
  with generous capacity returns the flat ``ShardedPlan`` unchanged.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stst

from _subproc import run_fake_device_subprocess
from repro.core.compress import compress_plan, get_codec
from repro.core.executor import DryRunExecutor, ShardedSimExecutor
from repro.core.hierarchy import (
    HierarchicalPlan, INNER_ENGINES, compile_hierarchical,
)
from repro.core.plan import ShardedPlan
from repro.core.reference import run_reference
from repro.core.shard import compile_sharded, shard_working_set
from repro.core.stencil import get_stencil

RNG = np.random.default_rng(17)

# global framed 48x48 on a (2,2) mesh: ly = lx = 24; star2d1r with
# k_ici = 2 gives hk = 2 (band 28x28), box2d2r gives hk = 4 (band 32x32)
Y = X = 48
MESH = (2, 2)
N, K_ICI = 8, 2
INNER_D = 3      # every shard streams through >= 3 inner chunks


def _domain(seed=None):
    rng = RNG if seed is None else np.random.default_rng(seed)
    return rng.standard_normal((Y, X)).astype(np.float32)


def _hier(stencil="star2d1r", engine="so2dr", codec=None, **kw):
    if engine == "box_tb":
        kw.setdefault("inner_tiles", (INNER_D, 2))
    else:
        kw.setdefault("inner_d", INNER_D)
    return compile_hierarchical(stencil, Y, X, N, K_ICI, MESH,
                                inner_engine=engine, codec=codec, **kw)


# ------------------------------------------------- differential execution


@pytest.mark.parametrize("codec", [None, "zrle"])
@pytest.mark.parametrize("engine", sorted(INNER_ENGINES))
@pytest.mark.parametrize("stencil", ["star2d1r", "box2d2r"])
def test_hier_sim_bit_identical_to_flat_and_matches_reference(
        stencil, engine, codec):
    """Chunked masked execution inside each shard is a pure reordering:
    the hierarchical plan's output equals the flat sharded plan's bit
    for bit (lossless codecs included), and both match the oracle."""
    x = _domain(seed=3)
    plan = _hier(stencil, engine, codec)
    assert isinstance(plan, HierarchicalPlan)
    assert plan.inner_chunks >= 3
    flat = compile_sharded(stencil, Y, X, N, K_ICI, MESH)
    got, s_got = ShardedSimExecutor().execute(plan, x)
    want, _ = ShardedSimExecutor().execute(flat, x)
    np.testing.assert_array_equal(got, want)
    ref = np.asarray(run_reference(jnp.asarray(x), get_stencil(stencil), N))
    scale = np.abs(ref).max() + 1e-6
    assert np.abs(got - ref).max() / scale < 1e-5
    assert s_got == plan.stats()


def test_hier_lossy_codec_stays_within_its_error_bound():
    x = _domain(seed=5)
    got, _ = ShardedSimExecutor().execute(_hier(codec="bf16"), x)
    want, _ = ShardedSimExecutor().execute(_hier(), x)
    scale = np.abs(want).max() + 1e-6
    err = np.abs(got - want).max() / scale
    assert 0 < err < 64 * get_codec("bf16").max_rel_error


_SUBPROC = r"""
import numpy as np, jax.numpy as jnp
from repro.compat import AxisType, make_mesh
from repro.core.executor import ShardMapExecutor, ShardedSimExecutor
from repro.core.hierarchy import compile_hierarchical
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil

mesh = make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
x = np.random.default_rng(7).standard_normal((48, 48)).astype(np.float32)
ref = np.asarray(run_reference(jnp.asarray(x), get_stencil("star2d1r"), 8))
scale = np.abs(ref).max() + 1e-6
for engine, kw in [("so2dr", dict(inner_d=3)), ("resreu", dict(inner_d=4)),
                   ("box_tb", dict(inner_tiles=(3, 2)))]:
    for codec in (None, "zrle"):
        plan = compile_hierarchical("star2d1r", 48, 48, 8, 2, (2, 2),
                                    inner_engine=engine, codec=codec, **kw)
        assert plan.inner_chunks >= 3, (engine, plan.inner_chunks)
        got_sm, s_sm = ShardMapExecutor(mesh=mesh).execute(plan, x)
        got_sim, s_sim = ShardedSimExecutor().execute(plan, x)
        assert np.abs(got_sm - ref).max() / scale < 1e-5, (engine, codec)
        assert np.abs(got_sim - ref).max() / scale < 1e-5, (engine, codec)
        assert np.abs(got_sim - got_sm).max() / scale < 1e-5, (engine, codec)
        assert s_sm == s_sim == plan.stats(), (engine, codec)
print("HIERARCHY_OK")
"""


def test_hier_sim_matches_shard_map_subprocess():
    """Every inner engine x {identity, zrle}: simulator == shard_map
    backend == run_reference on real fake devices, stats identical."""
    run_fake_device_subprocess(_SUBPROC, "HIERARCHY_OK")


# ------------------------------------------------- two-level accounting


def test_dry_run_stats_equal_executed_stats_at_both_levels():
    x = _domain()
    plan = _hier(codec="zrle")
    _, dry = DryRunExecutor().execute(plan)
    _, executed = ShardedSimExecutor().execute(plan, x)
    assert dataclasses.asdict(dry) == dataclasses.asdict(executed)
    # outer level: ICI fields come from the outer streams alone
    outer = plan.outer.stats()
    assert dry.ici_bytes == outer.ici_bytes
    assert dry.ici_wire_bytes == outer.ici_wire_bytes
    assert dry.halo_ops == outer.halo_ops
    # inner level: H2D/D2H roll up as (per-round inner plan) x rounds
    for field in ("h2d_bytes", "d2h_bytes", "h2d_wire_bytes",
                  "d2h_wire_bytes", "buffer_bytes"):
        inner_total = sum(getattr(plan.inner_stats(r), field)
                          for r in range(plan.n_ranks)) * plan.rounds
        assert getattr(dry, field) == inner_total, field


def test_hier_elements_account_for_inner_apron_overcompute():
    """Inner chunk aprons re-run masked updates the flat plan computes
    once: exact work is unchanged, total work strictly grows."""
    plan = _hier()
    flat = compile_sharded("star2d1r", Y, X, N, K_ICI, MESH)
    assert plan.exact_elements == flat.exact_elements
    assert plan.stats().elements_computed > flat.stats().elements_computed


def test_compressed_halos_cut_wire_bytes_not_payload():
    flat = compile_sharded("star2d1r", Y, X, N, K_ICI, MESH)
    z = compress_plan(flat, "zrle")
    assert z.stats().ici_bytes == flat.stats().ici_bytes
    assert z.stats().ici_wire_bytes < z.stats().ici_bytes
    assert flat.stats().ici_wire_bytes == flat.stats().ici_bytes
    # the hierarchical wrapper routes its outer halos the same way
    h = _hier(codec="zrle")
    assert h.stats().ici_wire_bytes == z.stats().ici_wire_bytes
    assert h.stats().ici_bytes == z.stats().ici_bytes


# ------------------------------------------------- strict no-op flat path


def test_fitting_shard_compiles_bit_identical_flat_plan():
    """Expansion is a strict no-op when every shard fits ``c_dev``: the
    planner returns the flat ShardedPlan itself, equal field-for-field
    to a direct compile_sharded call."""
    plan = compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                                c_dev=1 << 30)
    flat = compile_sharded("star2d1r", Y, X, N, K_ICI, MESH)
    assert isinstance(plan, ShardedPlan)
    assert not isinstance(plan, HierarchicalPlan)
    assert plan == flat
    # with a codec: the no-op path still compresses the flat plan
    z = compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                             c_dev=1 << 30, codec="zrle")
    assert z == compress_plan(flat, "zrle")


def test_capacity_derives_inner_chunks_and_stays_exact():
    x = _domain(seed=9)
    flat = compile_sharded("star2d1r", Y, X, N, K_ICI, MESH)
    hk = K_ICI * get_stencil("star2d1r").radius
    ws = shard_working_set(Y // 2, X // 2, hk, 4)
    plan = compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                                c_dev=ws // 2)
    assert isinstance(plan, HierarchicalPlan)
    assert plan.inner_chunks >= 2 and plan.c_dev == ws // 2
    got, _ = ShardedSimExecutor().execute(plan, x)
    want, _ = ShardedSimExecutor().execute(flat, x)
    np.testing.assert_array_equal(got, want)


def test_trailing_hierarchical_plans_are_dry_run_only():
    plan = compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                                inner_d=INNER_D, trailing=(64,))
    assert plan.stats().h2d_bytes > 0      # accounting still works
    with pytest.raises(ValueError, match="dry-run-only"):
        ShardedSimExecutor().execute(plan, _domain())


# ------------------------------------------------- property tests


@settings(max_examples=20, deadline=None)
@given(d=stst.integers(min_value=1, max_value=8),
       engine=stst.sampled_from(("so2dr", "resreu")))
def test_inner_h2d_bytes_sum_to_subdomain_plus_aprons(d, engine):
    """Per round, one shard's inner H2D traffic is exactly its band:
    resreu re-loads the 2*hk apron rows at every chunk seam
    ((ly + 2*hk*d) rows), so2dr loads every band row exactly once
    (fresh rows + the carry buffer replaces the re-load)."""
    plan = compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                                inner_engine=engine, inner_d=d)
    hk = K_ICI * get_stencil("star2d1r").radius
    h = Y // MESH[0] + 2 * hk
    w = X // MESH[1] + 2 * hk
    itemsize = plan.itemsize
    for rank in range(plan.n_ranks):
        s = plan.inner_stats(rank)
        if engine == "resreu":
            assert s.h2d_bytes == (Y // MESH[0] + 2 * hk * d) * w * itemsize
        else:
            assert s.h2d_bytes == h * w * itemsize
        # owned region comes back exactly once per round, apron-free
        assert s.d2h_bytes == (Y // MESH[0]) * (X // MESH[1]) * itemsize


@settings(max_examples=20, deadline=None)
@given(codec=stst.sampled_from(("identity", "zrle")),
       rows=stst.integers(min_value=1, max_value=6),
       cols=stst.integers(min_value=3, max_value=40),
       seed=stst.integers(min_value=0, max_value=2**31))
def test_lossless_halo_codecs_round_trip_bit_exact(codec, rows, cols, seed):
    """A full halo-exchange round trip (encode -> wire -> decode) must
    reproduce every fp32 bit pattern, specials included."""
    c = get_codec(codec)
    rng = np.random.default_rng(seed)
    band = rng.standard_normal((rows, cols)).astype(np.float32)
    band[0, 0] = -0.0
    if rows * cols > 2:
        band.flat[1], band.flat[2] = np.inf, np.nan
    out = c.decode(c.encode(band), band.shape, band.dtype)
    assert np.array_equal(band.view(np.uint32), out.view(np.uint32))


def test_lossless_codec_bit_exact_through_executed_exchange():
    """End to end, not just per-band: a zrle-compressed hierarchical run
    equals the uncompressed run bit for bit."""
    x = _domain(seed=23)
    got, _ = ShardedSimExecutor().execute(_hier(codec="zrle"), x)
    want, _ = ShardedSimExecutor().execute(_hier(), x)
    assert np.array_equal(np.asarray(got).view(np.uint32),
                          np.asarray(want).view(np.uint32))


# ------------------------------------------------- validation surface


def test_unknown_inner_engine_and_bad_knobs_are_rejected():
    with pytest.raises(ValueError, match="inner engine"):
        compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                             inner_engine="naive_tb", inner_d=2)
    with pytest.raises(ValueError, match="inner_tiles"):
        compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                             inner_engine="so2dr", inner_tiles=(2, 2))
    with pytest.raises(ValueError):
        compile_hierarchical("star2d1r", Y, X, N, K_ICI, MESH,
                             inner_engine="so2dr", inner_d=10**6)
