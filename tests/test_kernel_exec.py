"""Kernel-backed compiled execution: lowering, dispatch, and the cache.

The lowered executors must (a) be bit-identical to the legacy op-at-a-time
interpreter for every engine, (b) drive the real Pallas kernels (interpret
mode) through the dispatch registry and still match the oracle, and
(c) compile at most one kernel per *shape bucket* — not per chunk x round
— with the counters to prove it in :class:`repro.core.lower.ExecStats`.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.compress import CODECS
from repro.core.executor import DoubleBufferedExecutor, EagerExecutor
from repro.core.lower import KernelCache, lower
from repro.core.oocore import ENGINES, compile_plan
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil
from repro.kernels.dispatch import (
    DispatchPolicy, KERNEL_IMPLS, modeled_kernel_time, select_kernel,
)

RNG = np.random.default_rng(31)


def _domain(st, rows, cols=40):
    Y, X = rows + 2 * st.radius, cols + 2 * st.radius
    return RNG.standard_normal((Y, X)).astype(np.float32)


def _plan(engine, st, x, n=4, d=2, k_off=2, k_on=2, codec=None):
    d_eff = 1 if engine == "incore" else d
    return compile_plan(engine, st, x.shape[0], x.shape[1], n, d_eff,
                        k_off, k_on, codec=codec)


# ------------------------------------------------- lowered vs legacy


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_lowered_executors_bitwise_match_legacy(engine):
    """Lowering is a pure compilation step: slot binding, stage programs,
    and shape-bucket padding must not change a single bit."""
    st = get_stencil("box2d2r")
    x = _domain(st, rows=48)
    plan = _plan(engine, st, x, n=8, d=4, k_off=4)
    for cls in (EagerExecutor, DoubleBufferedExecutor):
        lowered_out, lowered_stats = cls().execute(plan, x)
        legacy_out, legacy_stats = cls(lowered=False).execute(plan, x)
        np.testing.assert_array_equal(lowered_out, legacy_out)
        assert lowered_stats == legacy_stats


# ------------------------------------------------- kernel-backed execution


@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("name", ["box2d1r", "gradient2d"])
def test_pallas_backed_execution_matches_oracle(engine, name):
    """Every engine, fused step dispatched to the Pallas kernel
    (interpret mode): within fp tolerance of the oracle and of the
    reference-fused run, and bit-identical between the eager and the
    pipelined executor (pipelining is a pure reordering)."""
    st = get_stencil(name)
    x = _domain(st, rows=32, cols=32)
    plan = _plan(engine, st, x)
    n = plan.n
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    scale = np.abs(ref).max() + 1e-6

    out_ref_step, _ = EagerExecutor().execute(plan, x)
    policy = DispatchPolicy(impl="pallas", tile=(8, 32))
    ex = EagerExecutor(policy=policy)
    out, _ = ex.execute(plan, x)
    out_db, _ = DoubleBufferedExecutor(policy=policy).execute(plan, x)
    np.testing.assert_array_equal(out, out_db)
    # vs the jnp-fused run only fp-tolerance holds: XLA may fuse the tap
    # arithmetic differently inside the Pallas interpreter (one-ulp skew)
    assert np.abs(out - out_ref_step).max() / scale < 1e-5
    assert np.abs(out - ref).max() / scale < 1e-5
    assert ex.exec_stats.kernel_impl == "pallas"
    assert ex.exec_stats.kernel_calls > 0


def test_explicit_fused_step_and_other_impls():
    """An explicit fused_step callable overrides dispatch; the DMA-overlap
    and MXU kernels plug in through the same policy."""
    from repro.kernels.ops import kernel_fused_step

    st = get_stencil("box2d2r")
    x = _domain(st, rows=32, cols=32)
    plan = _plan("so2dr", st, x)
    base, _ = EagerExecutor().execute(plan, x)

    ex = EagerExecutor(fused_step=kernel_fused_step)
    out, _ = ex.execute(plan, x)
    assert ex.exec_stats.kernel_impl == "explicit"
    np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)

    for impl in ("pallas_db", "mxu"):
        ex = EagerExecutor(policy=DispatchPolicy(impl=impl, tile=(8, 32)))
        out, _ = ex.execute(plan, x)
        assert ex.exec_stats.kernel_impl == impl
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)


# ------------------------------------------------- cache / bucket counters


def test_so2dr_compiles_at_most_one_kernel_per_shape_bucket():
    """The acceptance bar: a d=8, 4-round SO2DR plan presents at most
    one kernel signature per shape bucket — not chunks x rounds."""
    st = get_stencil("box2d1r")
    x = _domain(st, rows=96, cols=48)
    plan = _plan("so2dr", st, x, n=16, d=8, k_off=4, k_on=2)
    rounds = 4
    ex = EagerExecutor()
    _, _ = ex.execute(plan, x)
    es = ex.exec_stats
    assert es.stage_count == 8 * rounds
    assert es.kernel_calls == 8 * rounds * 2          # split_steps(4, 2)
    assert es.kernel_compiles <= es.shape_buckets
    assert es.shape_buckets < es.kernel_calls         # bucketing collapses
    assert es.kernel_compiles + es.kernel_cache_hits == es.kernel_calls

    # without bucketing every distinct band height is its own signature
    ex_nb = EagerExecutor(policy=DispatchPolicy(bucket=False))
    out_nb, _ = ex_nb.execute(plan, x)
    assert ex_nb.exec_stats.kernel_compiles >= es.kernel_compiles
    out_b, _ = EagerExecutor().execute(plan, x)
    np.testing.assert_array_equal(out_b, out_nb)      # padding is invisible


def test_kernel_cache_shared_across_runs():
    """Re-executing through the same executor is all cache hits."""
    st = get_stencil("box2d1r")
    x = _domain(st, rows=48)
    plan = _plan("so2dr", st, x, n=8, d=4, k_off=4)
    ex = EagerExecutor()
    ex.execute(plan, x)
    first = ex.exec_stats
    assert first.kernel_compiles > 0
    ex.execute(plan, x)
    second = ex.exec_stats
    assert second.kernel_compiles == 0
    assert second.kernel_cache_hits == second.kernel_calls


def test_swapping_fused_step_never_serves_stale_kernel():
    """Mutating a live executor's fused_step between runs must execute
    the *new* callable (and count its signatures as fresh compiles), not
    replay the cached one."""
    from repro.core.reference import multi_step_band

    st = get_stencil("box2d1r")
    x = _domain(st, rows=48)
    plan = _plan("so2dr", st, x, n=4, d=4)
    calls = {"a": 0, "b": 0}

    def step_a(band, name, steps, keep_top=False, keep_bottom=False):
        calls["a"] += 1
        return multi_step_band(band, name, steps, keep_top, keep_bottom)

    def step_b(band, name, steps, keep_top=False, keep_bottom=False):
        calls["b"] += 1
        return multi_step_band(band, name, steps, keep_top, keep_bottom)

    ex = EagerExecutor(fused_step=step_a)
    ex.execute(plan, x)
    assert calls["a"] == ex.exec_stats.kernel_calls and calls["b"] == 0
    ex.fused_step = step_b
    ex.execute(plan, x)
    assert calls["b"] == ex.exec_stats.kernel_calls
    # new callable = new signatures, honestly counted as compiles
    assert ex.exec_stats.kernel_compiles == ex.exec_stats.shape_buckets


def test_exec_stats_op_counts_match_plan():
    st = get_stencil("gradient2d")
    x = _domain(st, rows=48)
    plan = _plan("resreu", st, x, n=4, d=4, k_off=2, k_on=1, codec="zrle")
    ex = DoubleBufferedExecutor()
    _, _ = ex.execute(plan, x)
    es = ex.exec_stats
    assert es.op_counts == plan.op_counts()
    assert set(es.op_wall_s) == set(es.op_counts)
    assert all(t >= 0.0 for t in es.op_wall_s.values())
    assert es.executor == "double_buffered"


def test_lower_describe_is_deterministic_and_execution_free():
    st = get_stencil("box2d1r")
    plan = compile_plan("so2dr", st, 98, 98, 16, 8, 4, 2)
    d1 = lower(plan).describe()
    d2 = lower(plan).describe()
    assert d1 == d2
    assert d1["stage_count"] == 32
    assert d1["shape_buckets"] >= 1
    # slots are reused (with the pipeline-safety delay), so the register
    # file stays far below one slot per (round, chunk) register name
    assert d1["reg_slots"] < 32


# ------------------------------------------------- identity fast path


def test_identity_codec_round_trip_is_skipped(monkeypatch):
    """The identity codec's encode/decode is a pure byte copy; executors
    must skip it entirely (the transfer op is already the copy) while
    keeping the plan's wire accounting."""
    st = get_stencil("box2d1r")
    x = _domain(st, rows=48)
    plan_id = _plan("so2dr", st, x, n=4, d=4, codec="identity")
    plan_raw = _plan("so2dr", st, x, n=4, d=4)
    base, _ = EagerExecutor().execute(plan_raw, x)

    def boom(*a, **k):
        raise AssertionError("identity codec round trip was not skipped")

    idc = CODECS["identity"]
    monkeypatch.setattr(idc, "encode", boom)
    monkeypatch.setattr(idc, "decode", boom)
    for cls in (EagerExecutor, DoubleBufferedExecutor):
        for lowered in (True, False):
            out, stats = cls(lowered=lowered).execute(plan_id, x)
            np.testing.assert_array_equal(out, base)
            assert stats.codec_ops == plan_id.op_counts()["Compress"] * 2


# ------------------------------------------------- dispatch registry


def test_dispatch_registry_selection():
    name, fn = select_kernel("box2d1r", 2)            # auto off-TPU
    assert name == "reference" and callable(fn)
    name, _ = select_kernel("box2d4r", 2, DispatchPolicy(backend="tpu"))
    assert name == "mxu"                              # mxu_wins at r=4
    name, _ = select_kernel("gradient2d", 2, DispatchPolicy(backend="tpu"))
    assert name == "pallas_db"                        # nonlinear: no mxu
    with pytest.raises(ValueError):
        select_kernel("gradient2d", 2, DispatchPolicy(impl="mxu"))
    with pytest.raises(KeyError):
        select_kernel("box2d1r", 2, DispatchPolicy(impl="warp_specialized"))
    assert set(KERNEL_IMPLS) >= {"reference", "pallas", "pallas_db", "mxu"}


def test_modeled_kernel_times_are_ordered():
    """reference streams HBM per step; the fused Pallas paths read the
    band once — and the overlapped variant can only be faster still."""
    from repro.core.analytic import TPU_V5E

    st = get_stencil("box2d1r")
    plan = compile_plan("so2dr", st, 404, 404, 40, 4, 10, 4)
    t_ref, _, _ = modeled_kernel_time(plan, TPU_V5E, "reference")
    t_p, _, _ = modeled_kernel_time(plan, TPU_V5E, "pallas")
    t_db, _, _ = modeled_kernel_time(plan, TPU_V5E, "pallas_db")
    assert t_db <= t_p
    assert t_db <= t_ref
    # nonlinear stencils cannot take the banded-MXU path
    plan_g = compile_plan("so2dr", get_stencil("gradient2d"),
                          404, 404, 40, 4, 10, 4)
    assert modeled_kernel_time(plan_g, TPU_V5E, "mxu") is None


def test_kernel_cache_counts_signatures():
    cache = KernelCache()
    fn = cache.lookup(("a", 1), lambda: "one")
    assert fn == "one" and cache.misses == 1 and cache.hits == 0
    assert cache.lookup(("a", 1), lambda: "two") == "one"
    assert cache.hits == 1 and len(cache) == 1


def test_autotune_sweeps_dispatch_policy():
    from repro.core.analytic import TPU_V5E
    from repro.core.autotune import autotune

    st = get_stencil("box2d1r")
    ranked = autotune(st, 256, 40, TPU_V5E, d_grid=(4,), s_tb_grid=(20, 40),
                      k_on_grid=(1, 2), kernel_impls=("reference", "pallas_db"),
                      codecs=("identity",))
    assert ranked
    impls = {c.kernel_impl for c in ranked}
    assert impls == {"reference", "pallas_db"}
    assert all(c.time_s > 0 for c in ranked)
    assert "kernel_impl" in ranked[0].config
