"""The unified tune() entry point: spec inference, ranking parity with
the deprecated per-mode sweeps, measured refinement, and the stable
top-level exports.

The load-bearing properties:

* ``tune()`` under the synthetic "paper RTX3080" profile reproduces the
  deprecated ``autotune`` rankings on the 48-config golden geometries —
  the redesign changed the spelling, not the selection;
* refinement never promotes a candidate whose measured time is worse
  than the incumbent's (property-tested with an injected measurement
  function);
* the old ``autotune*`` entry points still work, under
  ``DeprecationWarning``, returning the same types and values.
"""
import json
import os
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st_h

import repro
from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.autotune import BoxChoice, Choice, ShardedChoice
from repro.core.lower import ExecStats
from repro.core.stencil import get_stencil
from repro.core.tune import TuneResult, TuneSpec, tune

from test_calibrate import synthetic_profile

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_row_plans.json")


def golden_geometries():
    """(Y, n, d, k_off, k_on) per golden token, e.g. Y37X23n6d3ko2ki2."""
    with open(GOLDEN) as f:
        keys = json.load(f)
    toks = sorted({k.split("/")[2] for k in keys})
    geoms = []
    for t in toks:
        import re
        m = re.fullmatch(r"Y(\d+)X(\d+)n(\d+)d(\d+)ko(\d+)ki(\d+)", t)
        geoms.append(tuple(int(g) for g in m.groups()))
    return geoms


# ------------------------------------------------------------ TuneSpec


def test_spec_mode_inference():
    assert TuneSpec("box2d1r", 258, 8).mode == "row"
    assert TuneSpec("heat3d1r", (66, 66, 66), 8).mode == "box"
    assert TuneSpec("box2d1r", 258, 8, engines=("box_tb",)).mode == "box"
    assert TuneSpec("box2d1r", 2050, 8, mesh=4).mode == "sharded"
    assert TuneSpec("box2d1r", 2050, 8, mesh=(2, 2)).n_devices == 4


def test_spec_validation():
    with pytest.raises(ValueError, match="steps"):
        TuneSpec("box2d1r", 258, 0)
    with pytest.raises(ValueError, match="shape"):
        TuneSpec("box2d1r", (258, 0), 8)
    with pytest.raises(ValueError, match="mesh"):
        TuneSpec("box2d1r", 2050, 8, mesh=(2, 2, 2))
    with pytest.raises(ValueError, match="square"):
        tune(TuneSpec("box2d1r", (64, 32), 8))


# ------------------------------------------- parity with the old sweeps


def test_tune_matches_autotune_on_golden_geometries():
    """Under the synthetic paper-RTX3080 profile, tune() must reproduce
    the deprecated row sweep's full ranking on every golden geometry —
    config-for-config, time-for-time (the profile carries RTX3080's
    constants verbatim and no kernel-term overrides)."""
    from repro.core.autotune import _autotune

    prof = synthetic_profile()            # RTX3080_PAPER constants
    st = get_stencil("box2d1r")
    checked = 0
    for (Y, _X, n, d, ko, ki) in golden_geometries():
        # exact golden geometry (tiny: the Sec. IV-C filter prunes it
        # identically on both paths) and a scaled-up feasible variant of
        # the same (d, k_on) shape — parity must hold for both
        cases = [
            (Y, n, (d, d + 2), (ko, 2 * ko)),
            ((Y - 2 * st.radius) * 64 + 2 * st.radius, 640,
             (d, d + 2), (40, 80)),
        ]
        for (Yc, nc, d_grid, s_grid) in cases:
            spec = TuneSpec("box2d1r", Yc, nc, d_grid=d_grid,
                            s_tb_grid=s_grid, k_on_grid=(ki, 1),
                            codecs=("identity", "zrle", "bf16"))
            got = tune(spec, profile=prof)
            want = _autotune(st, Yc - 2 * st.radius, nc, RTX3080_PAPER,
                             d_grid=d_grid, s_tb_grid=s_grid,
                             k_on_grid=(ki, 1),
                             codecs=("identity", "zrle", "bf16"))
            assert [r.config for r in got] == [c.config for c in want]
            assert [r.modeled_s for r in got] == [c.time_s for c in want]
            assert all(r.profile_id == prof.profile_id for r in got)
            checked += len(got)
    assert checked > 0, "every golden geometry was infeasible"


def test_tune_row_matches_autotune_large():
    spec = TuneSpec("box2d1r", 38400 + 2, 640)
    got = tune(spec, hw=RTX3080_PAPER)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        want = repro.autotune(get_stencil("box2d1r"), 38400, 640,
                              RTX3080_PAPER)
    assert got and [r.config for r in got] == [c.config for c in want]


def test_tune_box_matches_autotune_box():
    spec = TuneSpec("heat3d1r", (130, 130, 130), 8, engines=("box_tb",),
                    box_tile_grid=((1, 1), (2, 2)), time_depth_grid=(1, 2),
                    k_on_grid=(1,), codecs=("identity",))
    got = tune(spec, hw=TPU_V5E)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        want = repro.autotune_box(get_stencil("heat3d1r"), (130, 130, 130),
                                  8, TPU_V5E,
                                  tile_grid=((1, 1), (2, 2)),
                                  time_depth_grid=(1, 2))
    assert got
    assert [r.config for r in got] == [c.config for c in want]
    assert [r.extras["redundancy"] for r in got] \
        == [c.redundancy for c in want]


def test_tune_sharded_matches_autotune_sharded_and_mesh_pin():
    # tune()'s sharded sweep now prices the halo-codec axis too, so the
    # parity oracle sweeps the same codec grid as TuneSpec's default
    spec = TuneSpec("box2d1r", 2050, 64, mesh=4)
    got = tune(spec, hw=TPU_V5E)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        want = repro.autotune_sharded(get_stencil("box2d1r"), 2050, 64,
                                      TPU_V5E, n_devices=4,
                                      codecs=spec.codecs)
    assert got
    assert [(r.config["mesh"], r.config["k_ici"], r.config["codec"])
            for r in got] \
        == [(c.mesh, c.k_ici, c.codec) for c in want]
    assert [r.modeled_s for r in got] == [c.time_s for c in want]
    assert all(r.extras["ici_wire_bytes"] <= r.extras["ici_bytes"]
               for r in got)
    pinned = tune(TuneSpec("box2d1r", 2050, 64, mesh=(2, 2)), hw=TPU_V5E)
    assert pinned and all(r.config["mesh"] == (2, 2) for r in pinned)


# ------------------------------------------------- deprecated wrappers


def test_old_entry_points_warn_and_return_same_types():
    st = get_stencil("box2d1r")
    with pytest.warns(DeprecationWarning, match="repro.tune"):
        row = repro.autotune(st, 256, 40, TPU_V5E, d_grid=(4,),
                             s_tb_grid=(20,), k_on_grid=(1,))
    assert row and all(isinstance(c, Choice) for c in row)
    with pytest.warns(DeprecationWarning, match="repro.tune"):
        box = repro.autotune_box(get_stencil("heat3d1r"), (130,) * 3, 8,
                                 TPU_V5E, tile_grid=((1, 1),),
                                 time_depth_grid=(1,))
    assert box and all(isinstance(c, BoxChoice) for c in box)
    with pytest.warns(DeprecationWarning, match="repro.tune"):
        sh = repro.autotune_sharded(st, 2050, 64, TPU_V5E, n_devices=4)
    assert sh and all(isinstance(c, ShardedChoice) for c in sh)


def test_top_level_exports():
    for name in ("tune", "TuneSpec", "TuneResult", "DeviceProfile",
                 "calibrate", "resolve_hardware",
                 "autotune", "autotune_box", "autotune_sharded"):
        assert name in repro.__all__, name
        assert hasattr(repro, name), name


# --------------------------------------------------- measured refinement


def _results(n):
    return [TuneResult(mode="row", engine="so2dr",
                       config={"engine": "so2dr", "d": 4, "s_tb": 20,
                               "k_on": 1, "codec": "identity",
                               "kernel_impl": "reference", "tile": None,
                               "rank": i},
                       modeled_s=0.001 * (i + 1), bottleneck="kernel")
            for i in range(n)]


@settings(max_examples=40, deadline=None)
@given(
    n=st_h.integers(min_value=1, max_value=8),
    budget=st_h.integers(min_value=1, max_value=10),
    seed=st_h.integers(min_value=0, max_value=10_000),
    fail_some=st_h.booleans(),
)
def test_refinement_never_promotes_measured_worse_than_incumbent(
        n, budget, seed, fail_some):
    """The re-rank invariant: whoever ends up ranked above the modeled
    incumbent must have measured no worse than the incumbent measured.
    Holds for every measurement outcome, including failed ones."""
    from repro.core.tune import _refine

    rng = np.random.default_rng(seed)
    ranked = _results(n)
    spec = TuneSpec("box2d1r", 258, 40)
    measured_of = {}

    def measure(spec_, res):
        if fail_some and rng.random() < 0.3:
            return None
        t = float(rng.uniform(1e-4, 1e-2))
        measured_of[res.config["rank"]] = t
        return (t, t * float(rng.uniform(0.5, 2.0)), None)

    out = _refine(ranked, spec, budget, measure)
    assert len(out) == n
    assert {r.config["rank"] for r in out} == set(range(n))
    incumbent = ranked[0].config["rank"]
    if incumbent not in measured_of:
        # one-sided evidence: the modeled order must stand
        assert [r.config["rank"] for r in out] \
            == [r.config["rank"] for r in ranked]
        return
    inc_t = measured_of[incumbent]
    for r in out:
        if r.config["rank"] == incumbent:
            break
        assert r.measured_s is not None and r.measured_s <= inc_t, (
            f"candidate {r.config['rank']} promoted above the incumbent "
            f"with measured {r.measured_s} > {inc_t}")
    # measured head is sorted by wall clock
    head = [r.measured_s for r in out if r.measured_s is not None]
    assert head == sorted(head)


def test_refinement_attaches_error_and_exec_stats_via_injected_measure():
    ranked_spec = TuneSpec("box2d1r", 258, 40, d_grid=(4,),
                           s_tb_grid=(20, 40), k_on_grid=(1, 2),
                           codecs=("identity",),
                           kernel_impls=("reference",))

    def measure(spec, res):
        es = ExecStats(executor="test")
        es.wall_s = res.modeled_s * 2
        return (res.modeled_s * 2, res.modeled_s, es)

    out = tune(ranked_spec, hw=TPU_V5E, budget=2, measure=measure)
    assert out
    top = out[0]
    assert top.measured_s == pytest.approx(top.modeled_s * 2)
    # err = (modeled_small - measured) / measured = -0.5 here
    assert top.model_error == pytest.approx(-0.5)
    assert top.exec_stats.modeled_s == pytest.approx(top.modeled_s)
    assert top.exec_stats.model_error == pytest.approx(-0.5)
    assert sum(r.measured_s is not None for r in out) == min(2, len(out))


def test_refinement_real_measured_runs():
    """End-to-end acceptance drill: tune() re-ranks its modeled top-k by
    real short runs on bucketed small domains, with model-vs-measured
    error attributed in ExecStats."""
    spec = TuneSpec("box2d1r", 296, 40, d_grid=(4,), s_tb_grid=(20, 40),
                    k_on_grid=(1, 2), codecs=("identity",),
                    kernel_impls=("reference",))
    prof = synthetic_profile(hw=TPU_V5E, profile_id="tpu-synthetic")
    out = tune(spec, profile=prof, budget=2)
    assert out
    measured = [r for r in out if r.measured_s is not None]
    assert measured, "no candidate measured"
    for r in measured:
        assert r.measured_s > 0
        assert r.model_error is not None
        assert r.exec_stats is not None
        assert r.exec_stats.model_error == pytest.approx(r.model_error)
        assert r.profile_id == "tpu-synthetic"
    ms = [r.measured_s for r in out if r.measured_s is not None]
    assert ms == sorted(ms)


def test_to_record_is_json_safe():
    out = tune(TuneSpec("box2d1r", 2050, 64, mesh=4), hw=TPU_V5E)
    rec = out[0].to_record()
    json.dumps(rec)          # must not raise
    assert rec["mode"] == "sharded"
    assert isinstance(rec["config"]["mesh"], list)


# ----------------------------------------------------- service plumbing


def test_service_accepts_profile(tmp_path):
    prof = synthetic_profile(hw=TPU_V5E, profile_id="tpu-synthetic")
    p = tmp_path / "prof.json"
    prof.save(str(p))
    svc = repro.StencilService(profile=str(p))
    assert svc.hw == TPU_V5E
    assert svc.service_stats()["profile_id"] == "tpu-synthetic"
    job = repro.StencilJob(shape=(40, 24), stencil="box2d1r", steps=4, d=2)
    x = np.random.default_rng(3).standard_normal((40, 24)).astype(np.float32)
    res = svc.run_solo(job, x)
    assert res.status == "ok" and res.predicted_s > 0

    bare = repro.StencilService()
    assert bare.service_stats()["profile_id"] is None
