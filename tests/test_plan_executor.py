"""Plan/execute architecture: one op schedule, three executors.

For every engine x paper stencil: the dry-run executor's plan-derived
TransferStats must equal the eager executor's field-for-field (accounting
is a property of the plan, not of execution), and the eager and
double-buffered executors must produce identical arrays matching the
oracle.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.executor import (
    DoubleBufferedExecutor, DryRunExecutor, EagerExecutor, get_executor,
)
from repro.core.oocore import ENGINES, compile_plan, get_engine
from repro.core.plan import (
    BufferRead, BufferWrite, D2H, FusedKernel, H2D, HostCommit,
)
from repro.core.reference import run_reference
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil

RNG = np.random.default_rng(11)

N, D, K_OFF, K_ON = 8, 4, 4, 2


def _domain(st, rows=64, cols=36):
    Y, X = rows + 2 * st.radius, cols + 2 * st.radius
    return RNG.standard_normal((Y, X)).astype(np.float32)


def _plan_for(engine, st, x):
    d = 1 if engine == "incore" else D
    return compile_plan(engine, st, x.shape[0], x.shape[1], N, d, K_OFF, K_ON)


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_dry_run_stats_equal_eager_stats(engine, name):
    st = get_stencil(name)
    x = _domain(st)
    plan = _plan_for(engine, st, x)
    _, dry = DryRunExecutor().execute(plan)        # no domain array at all
    _, eager = EagerExecutor().execute(plan, x)
    for f in dataclasses.fields(eager):
        assert getattr(dry, f.name) == getattr(eager, f.name), (engine, f.name)
    assert dry.redundancy == eager.redundancy


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_executors_match_oracle(engine, name):
    st = get_stencil(name)
    x = _domain(st)
    plan = _plan_for(engine, st, x)
    ref = np.asarray(run_reference(jnp.asarray(x), st, N))
    scale = np.abs(ref).max() + 1e-6
    out_eager, _ = EagerExecutor().execute(plan, x)
    out_db, _ = DoubleBufferedExecutor().execute(plan, x)
    assert np.abs(out_eager - ref).max() / scale < 1e-5, engine
    # pipelining is a pure reordering: results must be bitwise identical
    np.testing.assert_array_equal(out_eager, out_db)


def test_plan_ops_carry_provenance_and_bytes():
    st = get_stencil("box2d2r")
    x = _domain(st)
    plan = _plan_for("so2dr", st, x)
    X, itemsize = x.shape[1], x.dtype.itemsize
    rounds = -(-N // K_OFF)
    seen = set()
    for op in plan:
        if isinstance(op, HostCommit):
            continue
        assert 0 <= op.round < rounds
        assert 0 <= op.chunk < D
        seen.add(type(op))
        if isinstance(op, (H2D, D2H)):
            assert op.nbytes == op.box.volume * itemsize
            assert op.box.extent(1) == X
        elif isinstance(op, BufferWrite):
            assert op.nbytes == op.reg_box.volume * itemsize
        elif isinstance(op, BufferRead):
            assert op.nbytes == op.extent * X * itemsize
        elif isinstance(op, FusedKernel):
            assert op.hbm_bytes == \
                (op.shape_in[0] + op.shape_out[0]) * X * itemsize
    assert seen == {H2D, D2H, BufferWrite, BufferRead, FusedKernel}


def test_double_buffered_prefetches_next_chunk():
    """The pipelined schedule must put chunk i+1's H2D before chunk i's
    last kernel — visible in the stage structure the executor walks."""
    st = get_stencil("box2d1r")
    x = _domain(st)
    plan = _plan_for("so2dr", st, x)
    stages = plan.stages()
    chunk_keys = [k for k, _ in stages if k is not None]
    # one stage per (round, chunk), in schedule order, commits between rounds
    assert chunk_keys == [(r, c) for r in range(len(set(r for r, _ in chunk_keys)))
                          for c in range(D)]
    barrier_idx = [i for i, (k, _) in enumerate(stages) if k is None]
    assert len(barrier_idx) == len(set(r for r, _ in chunk_keys))


def test_breakdown_matches_stats():
    st = get_stencil("gradient2d")
    x = _domain(st)
    plan = _plan_for("resreu", st, x)
    s = plan.stats()
    b = plan.breakdown()
    assert b == {"h2d": s.h2d_bytes, "d2h": s.d2h_bytes,
                 "h2d_wire": s.h2d_wire_bytes, "d2h_wire": s.d2h_wire_bytes,
                 "odc": s.buffer_bytes, "ici": 0, "ici_wire": 0,
                 "kernel_hbm": s.kernel_hbm_bytes}
    # uncompressed plan: what crosses the wire is the raw payload
    assert b["h2d_wire"] == b["h2d"] and b["d2h_wire"] == b["d2h"]
    # single-device plans never cross the chip interconnect
    assert s.ici_bytes == 0 and s.halo_ops == 0


def test_get_executor_registry():
    assert type(get_executor("eager")) is EagerExecutor
    assert type(get_executor("double_buffered")) is DoubleBufferedExecutor
    assert type(get_executor("dry_run")) is DryRunExecutor
    with pytest.raises(KeyError):
        get_executor("speculative")


def test_run_api_is_compile_plus_eager():
    """The historical engine.run() facade returns exactly what
    compile + EagerExecutor return."""
    st = get_stencil("box2d1r")
    x = _domain(st)
    eng = get_engine("so2dr", d=D, k_off=K_OFF, k_on=K_ON)
    out_run, stats_run = eng.run(x, st, N)
    plan = eng.compile(x.shape[0], x.shape[1], st, N, itemsize=x.dtype.itemsize)
    out_ex, stats_ex = EagerExecutor().execute(plan, x)
    np.testing.assert_array_equal(out_run, out_ex)
    assert stats_run == stats_ex
