"""Minimal deterministic stand-in for ``hypothesis``.

The real dependency is declared in ``pyproject.toml`` (test extras), but
this container image does not ship it and installing packages is not an
option.  ``conftest.py`` installs this stub into ``sys.modules`` only when
the real package is absent, so environments with hypothesis installed are
unaffected.

Only the surface the test-suite uses is provided: ``given`` / ``settings``
decorators and the ``integers`` / ``sampled_from`` / ``booleans``
strategies.  Examples are drawn from a fixed-seed PRNG, so runs are
reproducible (no shrinking, no database).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def given(**strategy_kwargs):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xD0D0)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # pytest collects the wrapper: hide the strategy-filled parameters
        # so they are not mistaken for fixtures
        params = [
            p for p in inspect.signature(fn).parameters.values()
            if p.name not in strategy_kwargs
        ]
        wrapper.__signature__ = inspect.Signature(params)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register the stub as the ``hypothesis`` package in ``sys.modules``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.booleans = booleans
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
