"""Checkpoint/restart: atomic writes, keep-K GC, bitwise resume."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import DataSpec, SyntheticLM
from repro.models.api import build_model
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer


def test_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))},
            "t": (jnp.zeros(()), jnp.full((2,), 7))}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra_meta={"mesh": "16x16"})
    assert mgr.all_steps() == [2, 3]  # keep-2 GC
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 3 and meta["mesh"] == "16x16"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bitwise_resume(tmp_path):
    """Train 6 steps; train 3 + restart + 3: identical final params."""
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=16, global_batch=2))
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=6)

    def train(ckpt_dir, steps, resume):
        tc = TrainConfig(steps=steps, ckpt_every=3, ckpt_dir=ckpt_dir,
                         log_every=100)
        tr = Trainer(model, opt, tc, donate=False)
        params, _, losses = tr.run(jax.random.PRNGKey(0), data, resume=resume)
        return params, losses

    p_full, _ = train(str(tmp_path / "a"), 6, False)
    train(str(tmp_path / "b"), 3, False)           # writes step_2 ckpt
    p_resumed, _ = train(str(tmp_path / "b"), 6, True)  # resumes at step 3

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, {"x": jnp.ones((3,))})
    names = os.listdir(tmp_path)
    assert "step_00000007" in names
    assert not any(n.endswith(".tmp") for n in names)


def test_incomplete_step_dirs_invisible(tmp_path):
    """Crash artifacts — a step dir missing its payload or its meta
    marker, or a stale .tmp — never shadow the newest complete step."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"x": jnp.arange(4.0)}
    mgr.save(1, tree, extra_meta={"tag": "good"})
    # meta.json written but arrays.npz lost (torn write)
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "meta.json").write_text("{}")
    # arrays.npz written but crash before meta.json (the marker)
    os.makedirs(tmp_path / "step_00000003")
    np.savez(tmp_path / "step_00000003" / "arrays.npz", x=np.ones(4))
    # a stale tmp dir and a non-step name
    os.makedirs(tmp_path / "step_00000004.tmp")
    os.makedirs(tmp_path / "step_backup")
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    restored, meta = mgr.restore(tree)
    assert meta["tag"] == "good"
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(4.0))
