"""Sharded plans: per-device op streams with halo-exchange ops,
differentially tested against the shard_map oracle.

Three layers of evidence that one plan really drives the multi-chip
engine:

* differential execution — the lowered single-device simulator
  (``ShardedSimExecutor``, stage programs from ``lower_sharded``), the
  real ``shard_map``/``ppermute`` backend (``ShardMapExecutor``), the
  plan-free ``run_distributed`` oracle, and ``run_reference`` all agree
  to 1e-5 (multi-device cases in an 8-fake-device subprocess via
  ``tests/_subproc.py``);
* accounting — dry-run stats equal executed stats field-for-field for
  every sharded plan (mirroring ``tests/test_compress.py``);
* plan invariants (property tests on the hypothesis stub) — per-rank
  ICI bytes read off the HaloSend ops match the neighbour-count formula
  (and the legacy analytic ``collective_bytes_per_round`` for interior
  ranks), halo sends/recvs pair 1:1, and redundant ``elements_computed``
  follows the k_ici ghost-wedge formula.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hs

from _subproc import run_fake_device_subprocess
from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.autotune import autotune_sharded
from repro.core.distributed import collective_bytes_per_round
from repro.core.executor import (
    DryRunExecutor, ShardMapExecutor, ShardedSimExecutor, get_executor,
)
from repro.core.lower import lower_sharded
from repro.core.plan import HaloRecv, HaloSend
from repro.core.reference import run_reference
from repro.core.shard import compile_sharded, ghost_wedge_elements
from repro.core.stencil import get_stencil

RNG = np.random.default_rng(31)

MESHES = [(1, 1), (2, 2), (3, 3), (4, 2), (1, 4)]
STENCILS = ["box2d1r", "box2d2r", "gradient2d"]


def _domain(Y=48, X=48, seed=None):
    rng = RNG if seed is None else np.random.default_rng(seed)
    return rng.standard_normal((Y, X)).astype(np.float32)


# ------------------------------------------------- differential execution


_SUBPROC = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import AxisType, make_mesh
from repro.core.distributed import run_distributed
from repro.core.executor import ShardMapExecutor, ShardedSimExecutor
from repro.core.reference import run_reference
from repro.core.shard import compile_sharded
from repro.core.stencil import get_stencil

mesh = make_mesh((4, 2), ("data", "model"),
                 axis_types=(AxisType.Auto,) * 2)
rng = np.random.default_rng(7)
for name in ("box2d1r", "gradient2d", "box2d2r"):
    st = get_stencil(name)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    for n, k in [(6, 1), (6, 3), (8, 4)]:
        plan = compile_sharded(name, 64, 128, n, k, (4, 2))
        ref = np.asarray(run_reference(jnp.asarray(x), st, n))
        dist = np.asarray(run_distributed(jnp.asarray(x), name, n, k, mesh))
        got_sm, s_sm = ShardMapExecutor(mesh=mesh).execute(plan, x)
        got_sim, s_sim = ShardedSimExecutor().execute(plan, x)
        assert np.abs(dist - ref).max() < 1e-5, ("oracle", name, n, k)
        assert np.abs(got_sm - dist).max() < 1e-5, ("shard_map", name, n, k)
        assert np.abs(got_sim - dist).max() < 1e-5, ("sim", name, n, k)
        assert np.abs(got_sim - ref).max() < 1e-5, ("sim/ref", name, n, k)
        assert s_sm == s_sim, (name, n, k)
print("SHARD_PLAN_OK")
"""


def test_sharded_plan_matches_shard_map_oracle_subprocess():
    """d=8 mesh=(4,2): sharded-plan execution through lower.py stage
    programs == shard_map backend == run_distributed == run_reference."""
    run_fake_device_subprocess(_SUBPROC, "SHARD_PLAN_OK")


@pytest.mark.parametrize("name", STENCILS)
@pytest.mark.parametrize("mesh", MESHES)
def test_sim_executor_matches_reference(name, mesh):
    """The lockstep simulator needs no real devices: every mesh shape
    runs in-process against the single-device oracle."""
    st = get_stencil(name)
    x = _domain()
    n, k = 6, 3
    plan = compile_sharded(name, 48, 48, n, k, mesh)
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    out, _ = ShardedSimExecutor().execute(plan, x)
    assert np.abs(out - ref).max() < 1e-5, (name, mesh)


@settings(max_examples=15, deadline=None)
@given(
    name=hs.sampled_from(STENCILS),
    mesh=hs.sampled_from(MESHES),
    k_ici=hs.sampled_from([1, 2, 3]),
    seed=hs.integers(0, 2**16),
)
def test_dry_run_stats_equal_executed_stats(name, mesh, k_ici, seed):
    """Accounting is a property of the plan (mirrors test_compress):
    the zero-device dry run and the executing simulator report the same
    TransferStats field for field — including the new ICI fields."""
    x = _domain(seed=seed)
    plan = compile_sharded(name, 48, 48, 6, k_ici, mesh)
    _, dry = DryRunExecutor().execute(plan)
    _, run = ShardedSimExecutor().execute(plan, x)
    for f in dataclasses.fields(run):
        assert getattr(dry, f.name) == getattr(run, f.name), f.name
    if mesh != (1, 1):
        assert dry.ici_bytes > 0 and dry.halo_ops > 0


# ------------------------------------------------------- plan invariants


@settings(max_examples=20, deadline=None)
@given(
    name=hs.sampled_from(STENCILS),
    mesh=hs.sampled_from(MESHES),
    k_ici=hs.sampled_from([1, 2, 3]),
)
def test_per_rank_ici_bytes_match_collective_formula(name, mesh, k_ici):
    """Per-rank ICI bytes derived from the HaloSend ops equal the
    neighbour-count byte formula, and for interior ranks exactly the
    legacy analytic collective_bytes_per_round."""
    st = get_stencil(name)
    Y = X = 48
    plan = compile_sharded(name, Y, X, 6, k_ici, mesh)
    n_row, n_col = mesh
    ly, lx = Y // n_row, X // n_col
    hk = k_ici * st.radius
    full = collective_bytes_per_round((ly, lx), st.radius, k_ici, 4)
    total = 0
    for sh in plan.shards:
        nb_row = (sh.row > 0) + (sh.row + 1 < n_row)
        nb_col = (sh.col > 0) + (sh.col + 1 < n_col)
        expect = (nb_row * hk * lx + nb_col * hk * (ly + 2 * hk)) * 4
        got = plan.ici_bytes_per_round(sh.rank)
        assert got == expect, sh
        if nb_row == nb_col == 2:   # fully interior rank
            assert got == full, sh
        total += expect * plan.rounds
    s = plan.stats()
    assert s.ici_bytes == total
    assert plan.collective_bytes_per_round == max(
        plan.ici_bytes_per_round(r) for r in range(plan.n_ranks))
    assert sum(plan.per_rank_stats(r).ici_bytes
               for r in range(plan.n_ranks)) == total


@settings(max_examples=20, deadline=None)
@given(
    name=hs.sampled_from(STENCILS),
    mesh=hs.sampled_from(MESHES),
    k_ici=hs.sampled_from([1, 2, 3]),
)
def test_halo_sends_and_recvs_pair_exactly(name, mesh, k_ici):
    """Every HaloSend has exactly one matching HaloRecv in the
    destination rank's stream (same axis/depth/bytes/round), and the
    only unmatched recvs are the zero-fill mesh-edge pads."""
    plan = compile_sharded(name, 48, 48, 6, k_ici, mesh)
    sends, recvs, pads = [], [], 0
    for stream in plan.streams:
        for op in stream:
            if isinstance(op, HaloSend):
                assert op.nbytes > 0
                sends.append((op.rank, op.dst, op.axis, op.depth,
                              op.nbytes, op.round))
            elif isinstance(op, HaloRecv):
                if op.src < 0:
                    assert op.nbytes == 0
                    pads += 1
                else:
                    recvs.append((op.src, op.rank, op.axis, op.depth,
                                  op.nbytes, op.round))
    assert sorted(sends) == sorted(recvs)
    # 4 recv slots per rank per round; pads fill the missing neighbours
    assert pads + len(recvs) == 4 * plan.n_ranks * plan.rounds
    assert plan.stats().halo_ops == len(sends) + len(recvs)


@settings(max_examples=15, deadline=None)
@given(
    name=hs.sampled_from(STENCILS),
    mesh=hs.sampled_from(MESHES),
)
def test_ghost_wedge_redundancy_grows_with_k_ici(name, mesh):
    """Redundant elements_computed follows the ghost-wedge formula:
    each rank updates the interior part of its (l + 2*k*r - 2r) wide
    extended centre every step, so redundancy grows with the halo depth
    while exchanges shrink as 1/k."""
    st = get_stencil(name)
    Y = X = 48
    r = st.radius
    n = 6
    redundant = []
    for k_ici in (1, 2, 3):
        plan = compile_sharded(name, Y, X, n, k_ici, mesh)
        s = plan.stats()
        # independent re-derivation of the per-rank wedge clip: each
        # step updates the extended band's centre (inset r each side)
        # intersected with the global interior
        hk = k_ici * r
        expect = 0
        for sh in plan.shards:
            rows = min(sh.y1 + hk - r, Y - r) - max(sh.y0 - hk + r, r)
            cols = min(sh.x1 + hk - r, X - r) - max(sh.x0 - hk + r, r)
            expect += n * max(0, rows) * max(0, cols)
        assert s.elements_computed == expect, (name, mesh, k_ici)
        assert s.elements_computed == ghost_wedge_elements(
            Y, X, r, k_ici, n, mesh)
        assert s.exact_elements == n * (Y - 2 * r) * (X - 2 * r)
        redundant.append(s.redundant_elements)
    if mesh == (1, 1):
        assert redundant == [0, 0, 0]   # no wedges without neighbours
    else:
        assert redundant[0] < redundant[1] < redundant[2]


def test_breakdown_and_per_rank_stats():
    plan = compile_sharded("box2d2r", 48, 48, 6, 2, (2, 2))
    s = plan.stats()
    b = plan.breakdown()
    assert b["ici"] == s.ici_bytes > 0
    assert b["h2d"] == s.h2d_bytes == 48 * 48 * 4   # whole domain loaded once
    assert b["d2h"] == s.d2h_bytes == 48 * 48 * 4
    agg = [plan.per_rank_stats(r) for r in range(plan.n_ranks)]
    for field in ("h2d_bytes", "d2h_bytes", "ici_bytes", "halo_ops",
                  "kernel_calls", "flops", "elements_computed",
                  "exact_elements"):
        assert sum(getattr(p, field) for p in agg) == getattr(s, field), field
    counts = plan.op_counts()
    assert counts["ShardLoad"] == counts["ShardStore"] == plan.n_ranks
    assert counts["ShardKernel"] == plan.n_ranks * plan.rounds


def test_planner_rejects_infeasible_geometry():
    with pytest.raises(ValueError, match="divide evenly"):
        compile_sharded("box2d1r", 50, 48, 6, 1, (4, 2))
    with pytest.raises(ValueError, match="multiple of k_ici"):
        compile_sharded("box2d1r", 48, 48, 7, 2, (2, 2))
    with pytest.raises(ValueError, match="halo depth"):
        compile_sharded("box2d2r", 48, 48, 12, 6, (4, 1))  # hk=12 >= ly=12
    with pytest.raises(KeyError):
        compile_sharded("nope2d", 48, 48, 6, 1, (2, 2))


# ------------------------------------------------- lowering + registry


def test_lowered_streams_share_one_kernel_signature():
    """Uniform shards -> one compiled shard-kernel signature for every
    rank x round: the global origin is traced, not static."""
    plan = compile_sharded("box2d1r", 48, 48, 8, 2, (2, 2))
    ex = ShardedSimExecutor()
    out, _ = ex.execute(plan, _domain())
    es = ex.exec_stats
    assert es.executor == "sharded_sim"
    assert es.shape_buckets == 1
    assert es.kernel_compiles == 1
    n_kernels = plan.n_ranks * plan.rounds
    assert es.kernel_calls == n_kernels
    assert es.kernel_cache_hits == n_kernels - 1
    assert es.stage_count == len(plan.barriers)
    # re-running the same plan through the same executor is all hits
    out2, _ = ex.execute(plan, _domain(seed=1))
    assert ex.exec_stats.kernel_compiles == 0
    assert ex.exec_stats.kernel_cache_hits == n_kernels
    compiled = lower_sharded(plan)
    assert compiled.describe()["shape_buckets"] == 1
    assert compiled.n_slots == plan.n_ranks


def test_barrier_structure_orders_sends_before_recvs():
    """The global barrier structure is what makes lockstep execution
    deadlock-free: sends and recvs of one exchange never share a phase,
    and every phase's ops agree with its label."""
    plan = compile_sharded("box2d1r", 48, 48, 4, 2, (2, 2))
    phases = plan.phases()
    assert [label for label, _ in phases] == list(plan.barriers)
    for label, ops in phases:
        kinds = {type(op).__name__ for op in ops}
        if label.endswith("send"):
            assert kinds <= {"HaloSend"}
        elif label.endswith("recv"):
            assert kinds <= {"HaloRecv"}
        elif label.endswith("compute"):
            assert kinds == {"ShardKernel"}
        elif label == "load":
            assert kinds == {"ShardLoad"}
        elif label == "store":
            assert kinds == {"ShardStore"}
    for stream in plan.streams:
        assert [op.phase for op in stream] == sorted(op.phase for op in stream)


def test_executor_registry_has_sharded_executors():
    assert type(get_executor("sharded_sim")) is ShardedSimExecutor
    assert type(get_executor("shard_map")) is ShardMapExecutor
    # configuration these executors would silently drop is rejected
    for name in ("sharded_sim", "shard_map", "dry_run"):
        with pytest.raises(ValueError, match="fused_step/policy"):
            get_executor(name, fused_step=lambda *a: None)


def test_both_backends_reject_mismatched_dtype():
    """shard_map and the simulator must reject identically: a float64
    domain against an itemsize-4 plan is a byte-accounting lie, not a
    run (a (1,1) mesh keeps the shard_map path single-device)."""
    from repro.core.distributed import execute_sharded_plan

    plan = compile_sharded("box2d1r", 48, 48, 2, 1, (1, 1))
    x64 = _domain().astype(np.float64)
    with pytest.raises(ValueError, match="itemsize"):
        ShardedSimExecutor().execute(plan, x64)
    with pytest.raises(ValueError, match="itemsize"):
        execute_sharded_plan(plan, x64)


# ------------------------------------------------------------ autotune


def test_autotune_sharded_ranks_the_k_ici_trade():
    """With the latency term modeled, deeper k_ici buys fewer collective
    phases: the winner must beat the k=1 per-step-exchange baseline."""
    st = get_stencil("box2d2r")
    ranked = autotune_sharded(st, 512, 64, TPU_V5E, n_devices=8)
    assert ranked == sorted(ranked, key=lambda c: c.time_s)
    assert ranked[0].k_ici > 1
    assert {c.mesh for c in ranked} == {(1, 8), (2, 4), (4, 2), (8, 1)}
    best = ranked[0]
    base = min(c.time_s for c in ranked if c.k_ici == 1)
    assert best.time_s < base
    assert best.bottleneck in ("ici", "kernel")
    assert best.ici_bytes > 0 and best.redundancy > 0


def test_autotune_sharded_rejects_ici_less_hardware():
    with pytest.raises(ValueError, match="ICI"):
        autotune_sharded(get_stencil("box2d1r"), 64, 8, RTX3080_PAPER)


def test_autotune_sharded_skips_infeasible_candidates():
    """k_ici deeper than a shard must be skipped, not crash."""
    st = get_stencil("box2d4r")   # r=4: k=8 -> hk=32 >= ly=16 on (8,1)
    ranked = autotune_sharded(st, 128, 64, TPU_V5E, n_devices=8,
                              k_ici_grid=(1, 2, 4, 8))
    assert ranked
    assert all((c.mesh[0] == 1 or c.k_ici * 4 < 128 // c.mesh[0]) and
               (c.mesh[1] == 1 or c.k_ici * 4 < 128 // c.mesh[1])
               for c in ranked)


# ------------------------------------------------- golden-fixture pin


def test_sharded_plans_bit_identical_to_golden_fixture():
    """Every stencil x geometry x halo codec of the pre-hierarchy fixture
    must recompile to the exact same sharded schedule — shards, per-rank
    op streams, barriers, stats, breakdown, op counts, collective rates —
    and infeasible configs must fail with the exact same message.
    ``compile_hierarchical`` with generous capacity must return that very
    flat plan (expansion is a strict no-op when no shard needs it)."""
    import json
    import os
    import re

    from repro.core.compress import compress_plan
    from repro.core.hierarchy import compile_hierarchical

    def op_rec(op):
        t = type(op).__name__
        d = {"type": t}
        if t in ("ShardLoad", "ShardStore"):
            d.update(rank=op.rank, lo=list(op.box.lo), hi=list(op.box.hi),
                     nbytes=op.nbytes, round=op.round, phase=op.phase)
        elif t == "HaloSend":
            d.update(rank=op.rank, dst=op.dst, axis=op.axis, side=op.side,
                     depth=op.depth, nbytes=op.nbytes, round=op.round,
                     phase=op.phase)
        elif t == "HaloRecv":
            d.update(rank=op.rank, src=op.src, axis=op.axis, side=op.side,
                     depth=op.depth, nbytes=op.nbytes, round=op.round,
                     phase=op.phase)
        elif t == "ShardKernel":
            d.update(rank=op.rank, stencil=op.stencil, steps=op.steps,
                     gy0=op.gy0, gx0=op.gx0, h=op.h, w=op.w,
                     hbm_bytes=op.hbm_bytes, flops=op.flops,
                     elements=op.elements, round=op.round, phase=op.phase)
        elif t in ("HaloCompress", "HaloDecompress"):
            d.update(codec=op.codec, rank=op.rank, peer=op.peer,
                     axis=op.axis, side=op.side, direction=op.direction,
                     raw_nbytes=op.raw_nbytes, wire_nbytes=op.wire_nbytes,
                     round=op.round, phase=op.phase)
        return d

    path = os.path.join(os.path.dirname(__file__), "data",
                        "golden_sharded_plans.json")
    with open(path) as f:
        golden = json.load(f)
    assert golden, "golden fixture is empty"
    checked = errors = 0
    for key, rec in golden.items():
        stname, geom, meshs, codec = key.split("/")
        g = re.match(r"Y(\d+)X(\d+)n(\d+)k(\d+)", geom)
        Y, X, n, k = map(int, g.groups())
        mesh = tuple(map(int, re.match(r"mesh(\d+)x(\d+)", meshs).groups()))
        if "error" in rec:
            with pytest.raises(ValueError) as exc:
                compile_sharded(stname, Y, X, n, k, mesh)
            assert str(exc.value) == rec["error"], key
            errors += 1
            continue
        plan = compile_sharded(stname, Y, X, n, k, mesh)
        if codec != "identity":
            plan = compress_plan(plan, codec)
        m = rec["plan"]
        assert plan.codec == m["codec"], key
        assert plan.exact_elements == m["exact_elements"], key
        assert [dataclasses.asdict(s) for s in plan.shards] \
            == rec["shards"], key
        assert [[op_rec(op) for op in s] for s in plan.streams] \
            == rec["streams"], key
        assert [list(b) for b in plan.barriers] == rec["barriers"], key
        assert dataclasses.asdict(plan.stats()) == rec["stats"], key
        assert plan.breakdown() == rec["breakdown"], key
        assert plan.op_counts() == rec["op_counts"], key
        assert plan.collective_bytes_per_round \
            == rec["collective_bytes_per_round"], key
        assert plan.collective_wire_bytes_per_round \
            == rec["collective_wire_bytes_per_round"], key
        # the hierarchical compiler's flat path is a strict no-op
        hier = compile_hierarchical(
            stname, Y, X, n, k, mesh, c_dev=1 << 40,
            codec=None if codec == "identity" else codec)
        assert hier == plan, key
        checked += 1
    assert checked + errors == len(golden) and checked >= 36, \
        (checked, errors)
