"""Autotuner (paper Sec. VII future work): selection sanity + optimality."""
import dataclasses

import numpy as np

from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.autotune import autotune, optimization_target
from repro.core.oocore import get_engine
from repro.core.stencil import get_stencil


def test_best_choice_is_so2dr_when_kernel_bound():
    """On the paper's machine at r=1, kernels dominate -> SO2DR with
    multi-step kernels must beat every ResReu config."""
    st = get_stencil("box2d1r")
    ranked = autotune(st, 38400, 640, RTX3080_PAPER)
    assert ranked, "feasible set empty"
    best = ranked[0]
    assert best.engine == "so2dr" and best.k_on > 1
    best_resreu = min(c.time_s for c in ranked if c.engine == "resreu")
    assert best.time_s < best_resreu


def test_selector_prefers_fused_kernels_only_when_they_help():
    """On TPU v5e, box2d4r single-step kernels are already compute-bound
    (DESIGN.md §2): fusing steps cannot beat the compute roofline, so the
    best SO2DR config must not be materially faster than k_on=1."""
    st = get_stencil("box2d4r")
    ranked = autotune(st, 38400, 640, TPU_V5E, engines=("so2dr",))
    best = ranked[0]
    k1 = min(c.time_s for c in ranked if c.k_on == 1)
    assert best.time_s >= 0.95 * k1


def test_optimization_target_matches_paper_fig3():
    """The paper's preliminary experiment (Fig. 3b): large TB-step counts
    turn the workload kernel-bound."""
    st = get_stencil("box2d1r")
    tgt = optimization_target(st, 38400, 640, RTX3080_PAPER)
    assert tgt == "kernel"


def test_selected_config_predicted_stats_match_measured():
    """The sweep is costed on dry-run plans only; executing the winning
    config for real must reproduce the predicted accounting exactly."""
    from repro.core.accounting import predict_stats

    st = get_stencil("box2d1r")
    sz, n = 256, 40
    ranked = autotune(st, sz, n, TPU_V5E, d_grid=(4,),
                      s_tb_grid=(20, 40), k_on_grid=(1, 2, 4))
    assert ranked, "feasible set empty"
    best = ranked[0]
    Y = X = sz + 2 * st.radius
    x = np.random.default_rng(7).standard_normal((Y, X)).astype(np.float32)
    eng = get_engine(best.engine, d=best.d, k_off=best.s_tb, k_on=best.k_on,
                     codec=best.codec)
    _, measured = eng.run(x, st, n)
    predicted = predict_stats(best.engine, st, Y, X, n,
                              best.d, best.s_tb, best.k_on, codec=best.codec)
    for f in dataclasses.fields(measured):
        assert getattr(measured, f.name) == getattr(predicted, f.name), f.name


def test_ranked_times_are_sorted_and_positive():
    st = get_stencil("gradient2d")
    ranked = autotune(st, 12800, 320, TPU_V5E)
    times = [c.time_s for c in ranked]
    assert times == sorted(times)
    assert all(t > 0 for t in times)
    # every candidate satisfies the feasibility constraint k*r <= chunk
    for c in ranked:
        assert c.s_tb * st.radius <= (12800 // c.d)
