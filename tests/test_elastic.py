"""Elastic restart: checkpoint on a 4-device mesh, restore onto 2 devices.

Runs in a subprocess (8 fake devices, via ``tests/_subproc.py``) so the
main session stays single-device.
"""
from _subproc import run_fake_device_subprocess

_SUBPROC = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import AxisType, make_mesh
from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.launch.elastic import replan, reshard_restored

cfg = get_smoke_config("qwen3-0.6b")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

mesh4 = make_mesh((4, 2), ("data", "model"),
                  axis_types=(AxisType.Auto,) * 2)
sh4 = replan(cfg, jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0))), mesh4)
p4 = jax.tree.map(jax.device_put, params, sh4)

import tempfile
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, p4, extra_meta={"mesh": [4, 2]})

# "failure": restart on a smaller mesh (2 devices)
mesh2 = make_mesh((2, 1), ("data", "model"),
                  axis_types=(AxisType.Auto,) * 2)
restored, meta = mgr.restore(params)
sh2 = replan(cfg, jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0))), mesh2)
p2 = reshard_restored(restored, sh2)

for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# the resharded params still produce equivalent logits (bf16 compute +
# different cross-device reduction orders => tolerance, not bitwise)
batch = {"tokens": jnp.zeros((2, 8), jnp.int32), "labels": jnp.zeros((2, 8), jnp.int32)}
l_ref, _ = model.forward(params, batch)
with mesh2:
    l_new, _ = model.forward(p2, batch)
np.testing.assert_allclose(np.asarray(l_ref, np.float32), np.asarray(l_new, np.float32),
                           rtol=0.05, atol=0.05)
print("ELASTIC_OK")
"""


def test_elastic_reshard_subprocess():
    run_fake_device_subprocess(_SUBPROC, "ELASTIC_OK")
