"""Oracle-level tests: stencil registry + reference vs direct numpy."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.stencil import PAPER_BENCHMARKS, get_stencil, box_coeffs
from repro.core.reference import run_reference, step_band, multi_step_band


def test_registry_contains_paper_benchmarks():
    for name in PAPER_BENCHMARKS:
        st = get_stencil(name)
        assert st.name == name


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_box_flops_match_paper_table3(r):
    st = get_stencil(f"box2d{r}r")
    assert st.points == (2 * r + 1) ** 2
    assert st.flops_per_elem == 2 * (2 * r + 1) ** 2 - 1


def test_gradient2d_is_5_point_19_flops():
    st = get_stencil("gradient2d")
    assert st.points == 5 and st.flops_per_elem == 19 and st.radius == 1


@pytest.mark.parametrize("name", ["box2d1r", "box2d3r"])
def test_reference_step_vs_direct_numpy(name):
    st = get_stencil(name)
    r = st.radius
    rng = np.random.default_rng(0)
    x = rng.standard_normal((20, 24)).astype(np.float32)
    out = np.asarray(run_reference(jnp.asarray(x), st, 1))
    # direct convolution on the interior
    c = st.coeffs
    expect = x.copy()
    for i in range(r, 20 - r):
        for j in range(r, 24 - r):
            acc = 0.0
            for dy in range(2 * r + 1):
                for dx in range(2 * r + 1):
                    acc += c[dy, dx] * x[i - r + dy, j - r + dx]
            expect[i, j] = acc
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-6)


def test_frame_constant_over_time():
    st = get_stencil("box2d2r")
    rng = np.random.default_rng(1)
    x = rng.standard_normal((32, 32)).astype(np.float32)
    out = np.asarray(run_reference(jnp.asarray(x), st, 7))
    r = st.radius
    np.testing.assert_array_equal(out[:r], x[:r])
    np.testing.assert_array_equal(out[-r:], x[-r:])
    np.testing.assert_array_equal(out[:, :r], x[:, :r])
    np.testing.assert_array_equal(out[:, -r:], x[:, -r:])


def test_multi_step_band_equals_stepwise():
    st = get_stencil("gradient2d")
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((40, 40)).astype(np.float32))
    a = multi_step_band(x, st.name, 3, keep_top=True, keep_bottom=False)
    b = x
    for _ in range(3):
        b = step_band(b, st, keep_top=True, keep_bottom=False)
    # same algorithm; XLA may fuse/reorder fp across the jitted multi-step
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_box_coeffs_sum_to_one_and_nonseparable():
    for r in (1, 2, 3, 4):
        c = box_coeffs(r)
        assert abs(c.sum() - 1.0) < 1e-12
        # non-separable: rank > 1
        assert np.linalg.matrix_rank(c) > 1
