"""Stencil-as-a-service: warm caches, admission order, cross-job pipeline.

Covers the serving layer end to end: concurrent submission is
bit-identical to sequential eager execution, an unseen shape inside an
existing bucket compiles zero new kernels, admission is deadline-aware
shortest-predicted-first, the modeled interleaved makespan strictly
beats back-to-back, and the shared counters (KernelCache, ExecStats,
SlotPool) survive thread hammering without corruption.
"""
import threading

import numpy as np

from repro.core.autotune import predicted_makespan
from repro.core.analytic import TPU_V5E
from repro.core.executor import DoubleBufferedExecutor, EagerExecutor
from repro.core.lower import BucketRegistry, ExecStats, KernelCache, SlotPool
from repro.core.oocore import compile_plan
from repro.core.stencil import get_stencil
from repro.kernels.dispatch import DispatchPolicy
from repro.serve import (
    ScheduledJob, StencilJob, StencilService, admission_order,
    modeled_makespan,
)

RNG = np.random.default_rng(31)
POLICY = DispatchPolicy(impl="reference")

STEPS, D, S_TB, K_ON = 8, 4, 4, 2


def _job(shape, stencil="box2d1r", codec="identity", deadline=None):
    return StencilJob(shape=shape, stencil=stencil, steps=STEPS,
                      codec=codec, deadline=deadline, d=D, s_tb=S_TB,
                      k_on=K_ON)


def _x(shape):
    return RNG.standard_normal(shape).astype(np.float32)


def _eager_reference(job, x):
    st = get_stencil(job.stencil)
    plan = compile_plan(job.engine, st, *job.shape, job.steps, job.d,
                        job.s_tb, job.k_on, itemsize=4,
                        codec=None if job.codec == "identity" else job.codec)
    out, _ = EagerExecutor(policy=POLICY).execute(plan, x)
    return out


def test_concurrent_flush_bit_identical_to_sequential():
    svc = StencilService(policy=POLICY)
    jobs = [_job((66, 66)), _job((66, 66), stencil="gradient2d"),
            _job((50, 66), codec="zrle")]
    xs = [_x(j.shape) for j in jobs]
    ids = {}
    threads = [threading.Thread(
        target=lambda j=j, x=x: ids.__setitem__(svc.submit(j, x), (j, x)))
        for j, x in zip(jobs, xs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    results = {r.job_id: r for r in svc.flush()}
    assert set(results) == set(ids)
    for job_id, (job, x) in ids.items():
        assert np.array_equal(results[job_id].out, _eager_reference(job, x))


def test_warm_bucket_compiles_zero_new_kernels():
    svc = StencilService(policy=POLICY)
    svc.submit(_job((130, 130)), _x((130, 130)))
    [first] = svc.flush()
    assert first.exec_stats.kernel_compiles > 0
    hits0, misses0 = svc.kernel_cache.snapshot()
    # unseen Y inside the 130-bucket (same X, stencil, steps): every
    # band height routes to an already-compiled signature
    svc.submit(_job((106, 130)), _x((106, 130)))
    [warm] = svc.flush()
    hits1, misses1 = svc.kernel_cache.snapshot()
    assert misses1 == misses0
    assert warm.exec_stats.kernel_compiles == 0
    assert warm.exec_stats.kernel_cache_hits > 0
    assert hits1 > hits0
    # the warm result is still bit-identical to an uncached eager run
    # (height padding is on the frame-free side only)


def test_warm_bucket_result_bit_identical():
    svc = StencilService(policy=POLICY)
    svc.submit(_job((130, 130)), _x((130, 130)))
    svc.flush()
    job, x = _job((106, 130)), _x((106, 130))
    svc.submit(job, x)
    [warm] = svc.flush()
    assert warm.exec_stats.kernel_compiles == 0
    assert np.array_equal(warm.out, _eager_reference(job, x))


def test_admission_deadline_then_shortest_predicted():
    svc = StencilService(policy=POLICY)
    big = svc.submit(_job((130, 130)), _x((130, 130)))
    small = svc.submit(_job((66, 130)), _x((66, 130)))
    urgent = svc.submit(_job((130, 130), deadline=0.1), _x((130, 130)))
    later = svc.submit(_job((66, 130), deadline=0.9), _x((66, 130)))
    order = [r.job_id for r in svc.flush()]
    # deadlines first (earliest deadline), then best-effort by
    # shortest predicted makespan
    assert order == [urgent, later, small, big]
    sched = {j.job_id: j for j in svc.last_admission}
    assert sched[small].predicted_s < sched[big].predicted_s


def test_admission_order_pure_function():
    def mk(i, p, dl):
        return ScheduledJob(job_id=i, compiled=None, x=None,
                            predicted_s=p, deadline=dl)

    jobs = [mk(0, 5.0, None), mk(1, 1.0, None), mk(2, 9.0, 0.2),
            mk(3, 1.0, 0.5), mk(4, 2.0, None)]
    assert [j.job_id for j in admission_order(jobs)] == [2, 3, 1, 4, 0]


def test_modeled_interleaved_strictly_beats_back_to_back():
    svc = StencilService(policy=POLICY)
    svc.submit(_job((130, 130)), _x((130, 130)))
    svc.submit(_job((130, 130), stencil="gradient2d"), _x((130, 130)))
    svc.flush()
    mi = svc.modeled_makespan(interleaved=True)
    mb = svc.modeled_makespan(interleaved=False)
    assert 0 < mi < mb
    # and the module-level pricing agrees with the service method
    assert mi == modeled_makespan(svc.last_admission, TPU_V5E,
                                  interleaved=True)


def test_predicted_makespan_positive_and_monotone_in_size():
    st = get_stencil("box2d1r")
    small = compile_plan("so2dr", st, 66, 66, STEPS, D, S_TB, K_ON)
    big = compile_plan("so2dr", st, 130, 130, STEPS, D, S_TB, K_ON)
    assert 0 < predicted_makespan(small, TPU_V5E) \
        < predicted_makespan(big, TPU_V5E)


def test_kernel_cache_thread_hammer():
    cache = KernelCache()
    made = []
    barrier = threading.Barrier(8)

    def worker():
        barrier.wait()
        for i in range(200):
            key = ("sig", i % 10)
            cache.lookup(key, lambda k=key: made.append(k) or (lambda: k))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    hits, misses = cache.snapshot()
    assert hits + misses == 8 * 200
    assert misses == len(cache) == 10
    assert len(made) == 10          # each signature compiled exactly once


def test_exec_stats_merge_thread_safe():
    total = ExecStats(executor="service")
    part = ExecStats(kernel_calls=3, kernel_compiles=1, kernel_cache_hits=2,
                     stage_count=4, shape_buckets=2, wall_s=0.5,
                     op_counts={"H2D": 2}, op_wall_s={"H2D": 0.1})
    threads = [threading.Thread(
        target=lambda: [total.merge(part) for _ in range(50)])
        for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    n = 8 * 50
    assert total.kernel_calls == 3 * n
    assert total.kernel_compiles == n
    assert total.op_counts["H2D"] == 2 * n
    assert abs(total.op_wall_s["H2D"] - 0.1 * n) < 1e-6


def test_slot_pool_reuse_and_clearing():
    pool = SlotPool()
    regs, bufs = pool.acquire(4, 2)
    regs[0], bufs[0] = "live", "live"
    pool.release(regs, bufs)
    regs2, bufs2 = pool.acquire(3, 1)
    assert regs2 is regs and bufs2 is bufs      # storage actually reused
    assert all(r is None for r in regs2) and all(b is None for b in bufs2)
    stats = pool.stats()
    assert stats["leases"] == 2 and stats["reuses"] == 1
    assert stats["in_use"] == 1 and stats["peak_in_use"] == 1


def test_bucket_registry_routes_to_smallest_fitting_bucket():
    reg = BucketRegistry()
    group = ("box2d1r", 2, True, False, 130, 4)
    assert reg.resolve(group, 64) == 64          # first height registers
    assert reg.resolve(group, 40) == 64          # smaller -> existing bucket
    assert reg.resolve(group, 100) == 100        # larger -> new bucket
    assert reg.resolve(group, 70) == 100         # smallest fitting wins
    assert reg.resolve(("other",) + group[1:], 40) == 40   # groups isolated
    assert len(reg) == 3


def test_executor_reentrant_thread_local_stats():
    st = get_stencil("box2d1r")
    ex = DoubleBufferedExecutor(policy=POLICY)
    plans = {
        "a": compile_plan("so2dr", st, 66, 66, STEPS, D, S_TB, K_ON),
        "b": compile_plan("so2dr", st, 130, 130, STEPS, D, S_TB, K_ON),
    }
    xs = {k: _x((p.Y, p.X)) for k, p in plans.items()}
    seen = {}
    barrier = threading.Barrier(2)

    def worker(k):
        barrier.wait()
        out, _ = ex.execute(plans[k], xs[k])
        # each thread reads its *own* run's stats, not the other's
        seen[k] = (out, ex.exec_stats.stage_count)

    threads = [threading.Thread(target=worker, args=(k,)) for k in plans]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for k in plans:
        expected = sum(1 for key, _ in plans[k].stages() if key is not None)
        assert seen[k][1] == expected
        assert np.array_equal(
            seen[k][0], EagerExecutor(policy=POLICY).execute(plans[k], xs[k])[0])
    # both plans live in the keyed memo: re-running either is a cache hit
    assert len(ex._lowered_memo) == 2


def test_serve_package_exports():
    import repro.serve as serve
    for name in ("StencilService", "StencilJob", "JobResult",
                 "ScheduledJob", "admission_order", "interleave_stages",
                 "modeled_makespan", "run_interleaved"):
        assert hasattr(serve, name)
    # the legacy LM decode driver stays importable (system test uses it)
    from repro.serve.decode import greedy_generate  # noqa: F401


def test_service_lifetime_stats_accumulate():
    svc = StencilService(policy=POLICY)
    svc.submit(_job((66, 66)), _x((66, 66)))
    svc.flush()
    svc.run_solo(_job((66, 66)), _x((66, 66)))
    s = svc.service_stats()
    assert s["jobs_submitted"] == s["jobs_completed"] == 2
    assert s["kernel_compiles"] > 0
    assert s["slot_pool"]["leases"] == 2
    assert svc.exec_stats.kernel_calls > 0


def test_hierarchical_job_runs_through_service_warm_state():
    """A hierarchical job shares the service's slot pool and kernel
    cache: inner chunk slots are leased from the pool (and all
    returned), and a second submission re-uses the masked kernel
    signature instead of re-tracing."""
    from repro.core.hierarchy import compile_hierarchical
    from repro.core.reference import run_reference
    import jax.numpy as jnp

    svc = StencilService()
    plan = compile_hierarchical("star2d1r", 48, 48, STEPS, 2, (2, 2),
                                inner_engine="so2dr", inner_d=3)
    x = _x((48, 48))
    res = svc.run_sharded(plan, x)
    assert res.status == "ok" and res.fault is None
    ref = np.asarray(run_reference(jnp.asarray(x),
                                   get_stencil("star2d1r"), STEPS))
    assert np.abs(res.out - ref).max() < 1e-5
    assert res.predicted_s > 0
    svc.slot_pool.assert_balanced()
    pool = svc.slot_pool.stats()
    assert pool["leases"] > 0 and pool["in_use"] == 0
    compiles0 = svc.service_stats()["kernel_compiles"]
    assert compiles0 > 0
    res2 = svc.run_sharded(plan, x)
    assert res2.exec_stats.kernel_compiles == 0
    assert res2.exec_stats.kernel_cache_hits > 0
    assert svc.service_stats()["kernel_compiles"] == compiles0
    svc.slot_pool.assert_balanced()


def test_no_leaked_leases_when_hierarchical_job_raises_mid_flush():
    """A terminal fault after round 0's nested programs have leased and
    released their chunk slots must leave the pool balanced: the job
    fails, the service survives, ``assert_balanced`` holds."""
    from repro.core.faults import KERNEL_FAULT, FaultPlan, FaultTrigger
    from repro.core.hierarchy import compile_hierarchical
    from repro.core.recovery import PlanExecutionError

    svc = StencilService()
    plan = compile_hierarchical("star2d1r", 48, 48, STEPS, 2, (2, 2),
                                inner_engine="so2dr", inner_d=3)
    faults = FaultPlan([FaultTrigger(round=1, chunk=None,
                                     op_class="ShardKernel",
                                     kind=KERNEL_FAULT)])
    res = svc.run_sharded(plan, _x((48, 48)), faults=faults)
    assert res.status == "failed" and res.out is None
    assert isinstance(res.fault, PlanExecutionError)
    svc.slot_pool.assert_balanced()
    pool = svc.slot_pool.stats()
    # round 0's four inner programs each leased (and returned) a slot
    assert pool["leases"] >= 4 and pool["in_use"] == 0
    assert svc.service_stats()["jobs_failed"] == 1
    # the pool is still serviceable: the same job reruns clean
    assert svc.run_sharded(plan, _x((48, 48))).status == "ok"
    svc.slot_pool.assert_balanced()


def test_assert_balanced_raises_on_outstanding_lease():
    pool = SlotPool()
    regs, bufs = pool.acquire(2, 1)
    try:
        import pytest
        with pytest.raises(AssertionError, match="1 lease"):
            pool.assert_balanced()
    finally:
        pool.release(regs, bufs)
    pool.assert_balanced()
