"""Trip-count-aware HLO analyzer: the roofline's measurement backbone."""
import jax
import jax.numpy as jnp

from repro.compat import AxisType, make_mesh, shard_map
from repro.launch.hlo_analysis import analyze_hlo


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile().as_text()


def test_scan_equals_unroll():
    def f_scan(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y ** 2)

    def f_unroll(w, x):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return jnp.sum(x ** 2)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cs = analyze_hlo(_compile(f_scan, w, x))
    cu = analyze_hlo(_compile(f_unroll, w, x))
    assert abs(cs.flops - cu.flops) / cu.flops < 0.01
    analytic = 10 * 2 * 128 ** 3
    assert abs(cs.flops - analytic) / analytic < 0.05


def test_grad_flops_ratio():
    def f(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(y ** 2)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fwd = analyze_hlo(_compile(f, w, x))
    vg = analyze_hlo(_compile(lambda w, x: jax.value_and_grad(f)(w, x), w, x))
    # dL/dw: 2 matmuls per layer in bwd + 1 fwd -> ~3x
    assert 2.5 < vg.flops / fwd.flops < 3.6


def test_nested_scan_multiplies():
    def f(x):
        def outer(x, _):
            def inner(x, _):
                return x @ x, None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = analyze_hlo(_compile(f, x))
    analytic = 15 * 2 * 64 ** 3
    assert abs(c.flops - analytic) / analytic < 0.05


def test_collectives_counted():
    mesh = make_mesh((1,), ("d",), axis_types=(AxisType.Auto,))

    def f(x):
        return jax.lax.psum(x @ x, "d")

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    with mesh:
        g = shard_map(f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),),
                      out_specs=jax.sharding.PartitionSpec(), check_vma=False)
        txt = jax.jit(g).lower(x).compile().as_text()
    c = analyze_hlo(txt)
    # single-device psum may fold away; just check the parser doesn't crash
    assert c.flops > 0
