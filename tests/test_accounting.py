"""predict_stats must equal the real engines' accounting bit-for-bit."""
import dataclasses

import numpy as np
import pytest

from repro.core.accounting import predict_stats
from repro.core.oocore import get_engine
from repro.core.stencil import get_stencil

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("engine", ["incore", "naive_tb", "resreu", "so2dr"])
@pytest.mark.parametrize("name,n,d,k_off,k_on", [
    ("box2d1r", 10, 4, 4, 3),
    ("box2d2r", 7, 3, 3, 2),
    ("gradient2d", 5, 2, 5, 5),
])
def test_predicted_equals_measured(engine, name, n, d, k_off, k_on):
    st = get_stencil(name)
    Y, X = 72 + 2 * st.radius, 40 + 2 * st.radius
    x = RNG.standard_normal((Y, X)).astype(np.float32)
    de = 1 if engine == "incore" else d
    _, real = get_engine(engine, d=de, k_off=k_off, k_on=k_on).run(x, st, n)
    pred = predict_stats(engine, st, Y, X, n, de, k_off, k_on, itemsize=4)
    for f in dataclasses.fields(real):
        assert getattr(real, f.name) == getattr(pred, f.name), (engine, f.name)
