"""The box-IR redesign's compatibility contract.

The plan IR moved from row ranges to N-D :class:`~repro.core.plan.Box`
coordinates.  These tests pin the redesign's promises:

* **bit-identity on the degenerate case** — 1-axis box chunking must
  reproduce the pre-redesign row planner exactly.  The golden fixture
  ``tests/data/golden_row_plans.json`` was generated *by the row-range
  code before the migration* (engines x stencils x codecs: full op
  schedules with row fields, TransferStats, breakdown, stage keys); the
  current planner must match it field for field, including infeasible
  configs' error messages;
* **axis generality** — a column-chunked plan of the transposed domain
  is the transpose of the row-chunked plan, in both geometry and
  executed output;
* **deprecation shims** — the old row accessors (``host_lo``/
  ``reg_hi``/``rows``/``keep_top``/...) still answer, with a
  ``DeprecationWarning``, and agree with the boxes they delegate to;
* **stable top-level API** — everything ``repro.__all__`` promises (and
  the names README leans on) resolves.
"""
import dataclasses
import json
import math
import os
import re
import warnings

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stst

from repro.core.executor import EagerExecutor
from repro.core.oocore import compile_plan, compile_plan_nd
from repro.core.plan import (
    Box, BufferRead, BufferWrite, Compress, D2H, Decompress, FusedKernel,
    H2D, HostCommit, ShardLoad,
)
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_row_plans.json")

ENGINE_NAMES = ("incore", "naive_tb", "resreu", "so2dr")


def _op_as_row_record(op):
    """Render a box-IR op in the pre-redesign row-field schema."""
    t = type(op).__name__
    d = {"type": t}
    if t == "H2D":
        d.update(reg=op.reg, host_lo=op.box.lo[0], host_hi=op.box.hi[0],
                 nbytes=op.nbytes, round=op.round, chunk=op.chunk)
    elif t == "D2H":
        d.update(reg=op.reg, reg_lo=op.reg_box.lo[0], reg_hi=op.reg_box.hi[0],
                 host_lo=op.box.lo[0], host_hi=op.box.hi[0],
                 nbytes=op.nbytes, round=op.round, chunk=op.chunk)
    elif t == "BufferWrite":
        d.update(buf=op.buf, reg=op.reg, reg_lo=op.reg_box.lo[0],
                 reg_hi=op.reg_box.hi[0], nbytes=op.nbytes, round=op.round,
                 chunk=op.chunk)
    elif t == "BufferRead":
        d.update(reg=op.reg, buf=op.buf, src=op.src, nbytes=op.nbytes,
                 rows=op.extent, round=op.round, chunk=op.chunk)
    elif t == "FusedKernel":
        d.update(reg=op.reg, stencil=op.stencil, steps=op.steps,
                 keep_top=op.keep_lo[0], keep_bottom=op.keep_hi[0],
                 h_in=op.shape_in[0], h_out=op.shape_out[0],
                 width=math.prod(op.shape_in[1:]), hbm_bytes=op.hbm_bytes,
                 flops=op.flops, elements=op.elements, round=op.round,
                 chunk=op.chunk)
    elif t in ("Compress", "Decompress"):
        d.update(codec=op.codec, reg=op.reg, direction=op.direction,
                 raw_nbytes=op.raw_nbytes, wire_nbytes=op.wire_nbytes,
                 host_lo=op.box.lo[0], host_hi=op.box.hi[0],
                 round=op.round, chunk=op.chunk)
    elif t == "HostCommit":
        d.update(nbytes=op.nbytes, round=op.round)
    return d


def test_one_axis_plans_bit_identical_to_golden_row_plans():
    """Every engine x config x codec of the pre-redesign fixture must
    recompile to the exact same schedule: ops (in the old field schema),
    stats, breakdown, op counts, stage keys — and infeasible configs must
    fail with the exact same message."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert golden, "golden fixture is empty"
    checked = errors = 0
    for key, rec in golden.items():
        eng, stname, geom, codec = key.split("/")
        codec_arg = None if codec == "identity" else codec
        if "error" in rec:
            g = re.match(r"Y(\d+)X(\d+)n(\d+)d(\d+)ko(\d+)ki(\d+)", geom)
            Y, X, n, d, ko, ki = map(int, g.groups())
            with pytest.raises(ValueError) as exc:
                compile_plan(eng, get_stencil(stname), Y, X, n, d, ko, ki,
                             codec=codec_arg)
            assert str(exc.value) == rec["error"], key
            errors += 1
            continue
        m = rec["plan"]
        plan = compile_plan(eng, get_stencil(m["stencil"]), m["Y"], m["X"],
                            m["n"], m["d"], m["k_off"], m["k_on"],
                            itemsize=m["itemsize"], codec=codec_arg)
        assert plan.exact_elements == m["exact_elements"], key
        assert [_op_as_row_record(op) for op in plan.ops] == rec["ops"], key
        assert dataclasses.asdict(plan.stats()) == rec["stats"], key
        assert plan.breakdown() == rec["breakdown"], key
        assert plan.op_counts() == rec["op_counts"], key
        stage_keys = [list(k) if k else None for k, _ in plan.stages()]
        assert stage_keys == rec["stage_keys"], key
        checked += 1
    assert checked + errors == len(golden) and checked >= 40, (checked, errors)


@settings(max_examples=8, deadline=None)
@given(engine=stst.sampled_from(ENGINE_NAMES),
       name=stst.sampled_from(("box2d1r", "star2d2r", "gradient2d")),
       Y=stst.integers(24, 60), X=stst.integers(20, 48),
       d=stst.integers(2, 4), k_off=stst.integers(1, 3))
def test_transposed_column_plan_mirrors_row_plan(engine, name, Y, X, d,
                                                 k_off):
    """chunk_axis=1 on the transposed domain is the transposed schedule:
    same stats/op counts, every box the transpose of its row twin."""
    st = get_stencil(name)
    n, k_on = 2 * k_off, 1
    try:
        row = compile_plan_nd(engine, st, (Y, X), n, d, k_off, k_on)
    except ValueError:
        with pytest.raises(ValueError):
            compile_plan_nd(engine, st, (X, Y), n, d, k_off, k_on,
                            chunk_axis=1)
        return
    col = compile_plan_nd(engine, st, (X, Y), n, d, k_off, k_on,
                          chunk_axis=1)
    assert dataclasses.asdict(row.stats()) == dataclasses.asdict(col.stats())
    assert row.op_counts() == col.op_counts()

    def t(box):
        return Box(box.lo[::-1], box.hi[::-1])

    for a, b in zip(row.ops, col.ops):
        assert type(a) is type(b)
        if isinstance(a, (H2D, Compress, Decompress)):
            assert t(a.box) == b.box
        elif isinstance(a, D2H):
            assert t(a.box) == b.box and t(a.reg_box) == b.reg_box
        elif isinstance(a, BufferWrite):
            assert t(a.reg_box) == b.reg_box
        elif isinstance(a, BufferRead):
            assert (a.extent, a.nbytes) == (b.extent, b.nbytes)
            assert b.axis == 1
        elif isinstance(a, FusedKernel):
            assert a.shape_in[::-1] == b.shape_in
            assert a.shape_out[::-1] == b.shape_out
            assert a.keep_lo[::-1] == b.keep_lo
            assert a.keep_hi[::-1] == b.keep_hi
            assert (a.hbm_bytes, a.flops, a.elements) == \
                   (b.hbm_bytes, b.flops, b.elements)

    # the column plan executes correctly (the stencil itself need not be
    # transpose-symmetric, so the oracle is the reference on the
    # transposed domain, not the transposed row output)
    rng = np.random.default_rng(Y * 1000 + X)
    xt = jnp.asarray(rng.standard_normal((X, Y)), jnp.float32)
    out_col = EagerExecutor().execute(col, xt)[0]
    ref = run_reference(xt, st, n)
    scale = float(jnp.abs(ref).max()) or 1.0
    assert float(jnp.max(jnp.abs(out_col - ref))) / scale < 1e-5


@settings(max_examples=6, deadline=None)
@given(engine=stst.sampled_from(ENGINE_NAMES),
       codec=stst.sampled_from((None, "bf16", "zrle")),
       d=stst.integers(2, 4))
def test_one_axis_execution_matches_reference(engine, codec, d):
    """All engines x codecs still run correctly through the box IR."""
    st = get_stencil("box2d1r")
    Y, X, n = 41, 33, 4
    plan = compile_plan(engine, st, Y, X, n, d, 2, 2, codec=codec)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((Y, X)), jnp.float32)
    out = EagerExecutor().execute(plan, x)[0]
    ref = run_reference(x, st, n)
    scale = float(jnp.abs(ref).max()) or 1.0
    # lossless paths agree to reference up to kernel-impl rounding;
    # bf16 pays its per-round-trip truncation bound
    tol = 1e-5 if codec != "bf16" else n * 2.0**-7
    assert float(jnp.max(jnp.abs(out - ref))) / scale <= tol


def test_deprecated_row_accessors_warn_and_delegate():
    """The old row-range fields survive as read-only properties on the
    1-axis case: each warns with DeprecationWarning and agrees with the
    box it delegates to."""
    st = get_stencil("box2d1r")
    plan = compile_plan("so2dr", st, 37, 23, 4, 2, 2, 2, codec="zrle")
    seen = set()
    for op in plan.ops:
        cases = []
        if isinstance(op, H2D):
            cases = [("host_lo", op.box.lo[0]), ("host_hi", op.box.hi[0])]
        elif isinstance(op, D2H):
            cases = [("host_lo", op.box.lo[0]), ("host_hi", op.box.hi[0]),
                     ("reg_lo", op.reg_box.lo[0]),
                     ("reg_hi", op.reg_box.hi[0])]
        elif isinstance(op, BufferWrite):
            cases = [("reg_lo", op.reg_box.lo[0]),
                     ("reg_hi", op.reg_box.hi[0])]
        elif isinstance(op, BufferRead):
            cases = [("rows", op.extent)]
        elif isinstance(op, FusedKernel):
            cases = [("keep_top", op.keep_lo[0]),
                     ("keep_bottom", op.keep_hi[0]),
                     ("h_in", op.shape_in[0]), ("h_out", op.shape_out[0]),
                     ("width", op.shape_in[1])]
        elif isinstance(op, (Compress, Decompress)):
            cases = [("host_lo", op.box.lo[0]), ("host_hi", op.box.hi[0])]
        for attr, want in cases:
            with pytest.warns(DeprecationWarning, match=attr):
                got = getattr(op, attr)
            assert got == want, (type(op).__name__, attr)
            seen.add((type(op).__name__, attr))
    # the sweep actually exercised every op family
    assert {name for name, _ in seen} >= {
        "H2D", "D2H", "BufferWrite", "BufferRead", "FusedKernel",
        "Compress", "Decompress"}

    load = ShardLoad(rank=0, box=Box((2, 3), (5, 9)), nbytes=0, round=0,
                     phase=0)
    for attr, want in (("y0", 2), ("x0", 3), ("y1", 5), ("x1", 9)):
        with pytest.warns(DeprecationWarning, match=attr):
            assert getattr(load, attr) == want


def test_fused_kernel_geometry_accounting_is_box_derived():
    """Bytes and elements on every op must equal what its box volumes
    say — the redesign's 'accounting derived from geometry' invariant."""
    st = get_stencil("star2d2r")
    plan = compile_plan("so2dr", st, 48, 36, 4, 3, 2, 2)
    itemsize = plan.itemsize
    for op in plan.ops:
        if isinstance(op, (H2D, D2H)):
            assert op.nbytes == op.box.volume * itemsize
        elif isinstance(op, BufferWrite):
            assert op.nbytes == op.reg_box.volume * itemsize
        elif isinstance(op, FusedKernel):
            vol_in = math.prod(op.shape_in)
            vol_out = math.prod(op.shape_out)
            assert op.hbm_bytes == (vol_in + vol_out) * itemsize
            assert op.flops == op.elements * st.flops_per_elem
        elif isinstance(op, HostCommit):
            assert op.nbytes >= 0


def test_top_level_api_is_stable():
    """repro.__all__ resolves completely and covers the names README and
    the redesign promise: Box, compile_plan, get_engine, get_executor,
    autotune, StencilService (+ the box-era additions)."""
    import repro

    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    required = {
        "Box", "compile_plan", "compile_plan_nd", "compile_box_plan",
        "get_engine", "get_executor", "autotune", "autotune_box",
        "compress_plan", "get_codec", "compile_sharded", "autotune_sharded",
        "run_reference", "get_stencil", "StencilService", "StencilJob",
    }
    assert required <= set(repro.__all__)

    # the engine/executor registries answer for every documented name
    for engine in ("incore", "naive_tb", "resreu", "so2dr", "box_tb"):
        assert repro.get_engine(engine, d=2, k_off=1, k_on=1).name == engine
    for executor in ("eager", "double_buffered", "dry_run"):
        assert repro.get_executor(executor).name == executor


def test_suite_reads_no_deprecated_fields():
    """Compiling and executing through the public paths emits no
    DeprecationWarning — the src tree is fully box-native."""
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((29, 27)), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plan = compile_plan("so2dr", st, 29, 27, 4, 2, 2, 2, codec="zrle")
        plan.stats(), plan.breakdown(), plan.op_counts(), list(plan.stages())
        EagerExecutor().execute(plan, x)
