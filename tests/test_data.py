"""Data pipeline: determinism, host sharding, prefetch."""
import numpy as np

from repro.data import DataSpec, SyntheticLM


def test_deterministic_by_step():
    d1 = SyntheticLM(DataSpec(vocab=100, seq_len=16, global_batch=4, seed=7))
    d2 = SyntheticLM(DataSpec(vocab=100, seq_len=16, global_batch=4, seed=7))
    b1, b2 = d1.batch(42), d2.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(42)["tokens"], d1.batch(43)["tokens"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(DataSpec(vocab=100, seq_len=16, global_batch=2))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_global_batch():
    full = SyntheticLM(DataSpec(vocab=50, seq_len=8, global_batch=4, n_hosts=1))
    h0 = SyntheticLM(DataSpec(vocab=50, seq_len=8, global_batch=4, n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataSpec(vocab=50, seq_len=8, global_batch=4, n_hosts=2, host_id=1))
    assert h0.batch(3)["tokens"].shape == (2, 8)
    # different hosts draw independent shards
    assert not np.array_equal(h0.batch(3)["tokens"], h1.batch(3)["tokens"])
    assert full.batch(3)["tokens"].shape == (4, 8)


def test_prefetch_iterator_matches_batch():
    d = SyntheticLM(DataSpec(vocab=60, seq_len=8, global_batch=2), prefetch=2)
    it = d.iterate(start_step=5)
    got = next(it)
    np.testing.assert_array_equal(got["tokens"], d.batch(5)["tokens"])


def test_learnable_structure():
    """The repetition process makes token t predictable from t-4 sometimes."""
    d = SyntheticLM(DataSpec(vocab=1000, seq_len=512, global_batch=2))
    b = d.batch(0)
    t = b["tokens"]
    match = (t[:, 4:] == t[:, :-4]).mean()
    assert match > 0.15  # far above chance
