"""Sec. IV-C run-time parameter heuristic: every candidate satisfies every
constraint (hypothesis over hardware/software configs)."""
from hypothesis import given, settings, strategies as st_h

from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.params import CodeSpec, enumerate_candidates, feasible


def test_paper_configs_are_feasible_on_paper_machine():
    """The paper uses d in {4,8} and S_TB in {40..640} for 38400^2 fp32."""
    code = CodeSpec(sz=38400, radius=1, b_elem=4, total_steps=640)
    cands = enumerate_candidates(code, RTX3080_PAPER)
    pairs = {(c.d, c.s_tb) for c in cands}
    assert (4, 160) in pairs  # the config the paper selects for box2d1r
    assert all(c.halo_fraction <= 1.0 for c in cands)


def test_feasible_set_nonempty_on_tpu():
    code = CodeSpec(sz=38400, radius=1, b_elem=4, total_steps=640)
    assert enumerate_candidates(code, TPU_V5E)


@settings(max_examples=30, deadline=None)
@given(
    sz=st_h.integers(4096, 65536),
    radius=st_h.integers(1, 4),
    d=st_h.sampled_from([4, 8, 16]),
    s_tb=st_h.sampled_from([40, 80, 160, 320]),
)
def test_feasible_implies_constraints(sz, radius, d, s_tb):
    code = CodeSpec(sz=sz, radius=radius, b_elem=4, total_steps=640)
    hw = TPU_V5E
    if feasible(code, hw, d, s_tb):
        d_chk = code.d_chk(d)
        w_tb = code.w_halo * s_tb
        assert (d_chk + w_tb) * hw.n_streams * code.b_elem <= hw.c_dmem
        assert w_tb <= d_chk
        assert d > hw.n_streams
