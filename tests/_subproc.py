"""Shared fake-device subprocess harness for multi-device tests.

JAX fixes its device topology at first backend use, so multi-device
tests (8 fake CPU devices via ``--xla_force_host_platform_device_count``)
must run in a subprocess to keep the main pytest session single-device.
This helper owns the env setup and the assert-runner pattern that
``test_distributed.py``, ``test_elastic.py``, and ``test_shard_plan.py``
previously each duplicated inline.
"""
import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_fake_device_subprocess(code: str, ok_token: str,
                               n_devices: int = 8,
                               timeout: int = 900) -> None:
    """Run ``code`` in a fresh interpreter with ``n_devices`` fake CPU
    devices and assert it printed ``ok_token``.

    ``XLA_FLAGS`` is set in the child's environment (before any jax
    import can happen), so the code string needs no ``os.environ``
    boilerplate.  On failure the child's stderr tail is the assertion
    message."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env,
        capture_output=True, text=True, timeout=timeout,
    )
    assert ok_token in out.stdout, out.stderr[-3000:]
