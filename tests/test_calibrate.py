"""Calibration harness: fits, profile round-trips, CLI + gates.

Covers the measured-cost profile subsystem end to end on the CPU
backend: the least-squares fit helpers recover known coefficients and
clamp degenerate ones, a DeviceProfile survives save/load bit-exactly,
the ``benchmarks/calibrate.py --quick`` CLI emits a loadable profile
that passes the ``check_regression.py --profile`` fit-sanity gate, and
``benchmarks/roofline.py`` exits 2 (with a pointer to the generating
command) instead of printing an empty table when the dry-run artifacts
are absent.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.core.analytic import RTX3080_PAPER, TPU_V5E, Hardware
from repro.core.calibrate import (
    DeviceProfile, ProfileError, calibrate, fit_affine, fit_two_term,
    resolve_hardware,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def synthetic_profile(hw=RTX3080_PAPER, profile_id="rtx3080-synthetic",
                      **overrides):
    """A hand-built profile carrying ``hw``'s constants verbatim — the
    "paper RTX3080" profile the tune-vs-autotune equality tests use."""
    fields = dict(
        profile_id=profile_id,
        fingerprint={"backend": "synthetic", "device_kind": hw.name},
        hardware=dataclasses.asdict(hw),
        kernel_terms={},
        codec_throughput={},
        residuals={"synthetic": 0.0},
        created_at="2026-01-01T00:00:00Z",
        base_hardware=hw.name,
    )
    fields.update(overrides)
    return DeviceProfile(**fields)


# ------------------------------------------------------------- fitters


def test_fit_affine_recovers_known_line():
    xs = [1e6, 4e6, 16e6]
    t0, rate = 5e-5, 2e9
    ts = [t0 + x / rate for x in xs]
    lat, slope, resid = fit_affine(xs, ts)
    assert lat == pytest.approx(t0, rel=1e-6)
    assert slope == pytest.approx(rate, rel=1e-6)
    assert resid < 1e-6


def test_fit_affine_clamps_negative_intercept_to_zero():
    # a fastest-rung fluke can drive the fitted intercept negative; the
    # fallback refits through the origin instead of keeping it
    xs = [1.0, 2.0, 3.0]
    ts = [0.9, 2.1, 3.3]            # least-squares intercept < 0
    lat, slope, resid = fit_affine(xs, ts)
    assert lat == 0.0
    assert slope > 0
    assert resid >= 0


def test_fit_two_term_recovers_known_rates():
    m1 = [1e6, 4e6, 1e6, 8e6]
    m2 = [2e6, 2e6, 8e6, 4e6]
    r1, r2 = 3e9, 5e9
    ts = [a / r1 + b / r2 for a, b in zip(m1, m2)]
    f1, f2, resid = fit_two_term(m1, m2, ts)
    assert f1 == pytest.approx(r1, rel=1e-4)
    assert f2 == pytest.approx(r2, rel=1e-4)
    assert resid < 1e-6


def test_fit_two_term_degenerate_falls_back_to_single_term():
    # pure compute-bound samples: the memory coefficient is unidentified;
    # the fallback pins it effectively-infinite but strictly positive
    m2 = [1e6, 2e6, 4e6, 8e6]
    m1 = [1.0, 1.0, 1.0, 1.0]
    ts = [b / 5e9 for b in m2]
    f1, f2, _ = fit_two_term(m1, m2, ts)
    assert f1 > 0 and f2 > 0
    assert f2 == pytest.approx(5e9, rel=0.01)


# ----------------------------------------------------- profile object


def test_profile_save_load_bit_exact(tmp_path):
    prof = synthetic_profile(
        kernel_terms={"reference": {"bw_eff": 1.5e9, "flops_eff": 4.2e9,
                                    "residual": 0.12, "n_points": 9}},
        codec_throughput={"zrle": {"encode_bps": 7e8, "decode_bps": 5e8,
                                   "residual": 0.3}},
    )
    p = tmp_path / "prof.json"
    prof.save(str(p))
    loaded = DeviceProfile.load(str(p))
    assert loaded == prof
    # byte-for-byte stable through a second round trip
    p2 = tmp_path / "prof2.json"
    loaded.save(str(p2))
    assert p.read_bytes() == p2.read_bytes()


def test_profile_as_hardware_drop_in():
    prof = synthetic_profile()
    hw = prof.as_hardware()
    assert isinstance(hw, Hardware)
    assert hw == RTX3080_PAPER


def test_profile_rejects_wrong_schema_version():
    d = dataclasses.asdict(synthetic_profile())
    d["schema_version"] = 999
    with pytest.raises(ProfileError, match="schema_version"):
        DeviceProfile.from_dict(d)


def test_profile_rejects_missing_fields():
    d = dataclasses.asdict(synthetic_profile())
    del d["hardware"]
    with pytest.raises(ProfileError, match="hardware"):
        DeviceProfile.from_dict(d)


def test_profile_load_missing_file_raises_profile_error(tmp_path):
    with pytest.raises(ProfileError, match="cannot read"):
        DeviceProfile.load(str(tmp_path / "nope.json"))


def test_resolve_hardware_coercions(tmp_path):
    assert resolve_hardware(None) is TPU_V5E
    assert resolve_hardware(RTX3080_PAPER) is RTX3080_PAPER
    prof = synthetic_profile()
    assert resolve_hardware(prof) == RTX3080_PAPER
    p = tmp_path / "p.json"
    prof.save(str(p))
    assert resolve_hardware(str(p)) == RTX3080_PAPER
    with pytest.raises(TypeError):
        resolve_hardware(42)


# ------------------------------------------------- real quick fit + CLI


@pytest.fixture(scope="module")
def quick_profile(tmp_path_factory):
    """One real --quick CLI calibration shared by the slow tests."""
    out = tmp_path_factory.mktemp("calib") / "BENCH_profile.json"
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.calibrate", "--quick",
         "--out", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    return out, r


def test_quick_cli_profile_loads_and_is_sane(quick_profile):
    out, r = quick_profile
    prof = DeviceProfile.load(str(out))
    hw = prof.as_hardware()
    assert hw.bw_intc > 0 and hw.bw_dmem > 0 and hw.peak_vpu_flops > 0
    assert hw.t_ici_latency >= 0
    assert "reference" in prof.kernel_terms
    assert set(prof.codec_throughput) >= {"identity", "bf16", "zrle"}
    assert prof.fingerprint["backend"]
    assert prof.profile_id.startswith(prof.fingerprint["backend"])
    # CSV rows went to stdout
    assert f"calibrate/{prof.profile_id}/bw_intc" in r.stdout


def test_quick_cli_profile_passes_fit_gate(quick_profile):
    out, _ = quick_profile
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--profile", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "fit sane" in r.stdout


def test_calibrate_api_quick_roundtrip(tmp_path):
    prof = calibrate(quick=True)
    p = tmp_path / "api.json"
    prof.save(str(p))
    assert DeviceProfile.load(str(p)) == prof


# ------------------------------------------------- fit-sanity gate unit


def test_check_profile_flags_bad_fits():
    from benchmarks.check_regression import check_profile

    good = json.loads(synthetic_profile().to_json())
    assert check_profile(good, residual_ceiling=5.0) == []

    bad = json.loads(synthetic_profile().to_json())
    bad["hardware"]["bw_dmem"] = 0.0
    bad["residuals"]["synthetic"] = 99.0
    bad["kernel_terms"] = {"reference": {"bw_eff": -1.0, "flops_eff": 1e9}}
    errors = check_profile(bad, residual_ceiling=5.0)
    assert any("bw_dmem" in e for e in errors)
    assert any("residual" in e for e in errors)
    assert any("bw_eff" in e for e in errors)

    wrong = {"schema_version": 2}
    assert any("schema_version" in e
               for e in check_profile(wrong, residual_ceiling=5.0))


# -------------------------------------------- roofline missing-artifact


def _run_roofline(art_dir):
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""),
               DRYRUN_ART=str(art_dir))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.roofline"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=120)


def test_roofline_missing_dir_exits_2_with_pointer(tmp_path):
    r = _run_roofline(tmp_path / "missing")
    assert r.returncode == 2
    assert "does not exist" in r.stderr
    assert "repro.launch.dryrun" in r.stderr


def test_roofline_empty_dir_exits_2_with_pointer(tmp_path):
    art = tmp_path / "empty"
    art.mkdir()
    r = _run_roofline(art)
    assert r.returncode == 2
    assert "no usable dry-run records" in r.stderr
    assert "repro.launch.dryrun" in r.stderr


def test_roofline_with_records_exits_0(tmp_path):
    art = tmp_path / "art"
    art.mkdir()
    rec = {
        "arch": "qwen3-0.6b", "shape": "train_4k", "multi_pod": False,
        "memory": {"temp_size_in_bytes": 2_000_000_000},
        "roofline": {"dominant": "memory", "t_compute": 0.001,
                     "t_memory": 0.002, "t_collective": 0.0005,
                     "useful_ratio": 0.8, "roofline_fraction": 0.5,
                     "t_memory_us": 2000.0},
    }
    (art / "cell.json").write_text(json.dumps(rec))
    r = _run_roofline(art)
    assert r.returncode == 0, r.stderr
    assert "roofline/qwen3-0.6b/train_4k" in r.stdout
