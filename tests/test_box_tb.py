"""BoxTB: N-D tile grids + temporal blocking on the box plan IR.

The paper's trade, one level down from the chip mesh: split the domain
into an N-D tile grid, load each tile with a ``t*r``-cell trapezoid
apron on every non-frame side, advance ``t`` steps per H2D round trip,
and write back only the owned interior box.  Deeper ``t`` divides the
transfer rounds while the aprons grow redundant compute — the same
redundancy-for-communication exchange as the sharded engine's
``k_ici``, here against host DRAM instead of ICI.
"""
import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as stst

from repro.core.analytic import RTX3080_PAPER
from repro.core.autotune import autotune_box, trapezoid_redundant_elements
from repro.core.executor import (
    DoubleBufferedExecutor, DryRunExecutor, EagerExecutor,
)
from repro.core.oocore import compile_box_plan
from repro.core.plan import D2H, H2D
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil


def test_heat3d_box_tb_matches_reference_with_temporal_blocking():
    """The acceptance run: a 3-D heat stencil out-of-core via box
    chunking with time depth >= 2, validated against the oracle."""
    st = get_stencil("heat3d1r")
    assert st.ndim == 3
    shape, n = (30, 26, 22), 6
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    ref = run_reference(x, st, n)
    scale = float(jnp.abs(ref).max())
    plan = compile_box_plan(st, shape, n, tiles=(2, 3, 2), time_depth=2)
    assert plan.k_off == 2 and plan.d == 12
    for ex in (EagerExecutor(), DoubleBufferedExecutor(),
               EagerExecutor(lowered=False)):
        out = ex.execute(plan, x)[0]
        assert float(jnp.max(jnp.abs(out - ref))) / scale <= 1e-5


@settings(max_examples=8, deadline=None)
@given(t0=stst.integers(1, 3), t1=stst.integers(1, 3), t2=stst.integers(1, 2),
       depth=stst.integers(1, 3), n=stst.integers(2, 6),
       name=stst.sampled_from(("heat3d1r", "box2d1r", "star2d2r")))
def test_trapezoid_redundancy_matches_closed_form(t0, t1, t2, depth, n,
                                                  name):
    """Plan-derived redundant elements equal the trapezoid-apron closed
    form, for 2-D and 3-D domains, any tile grid x time depth."""
    st = get_stencil(name)
    r = st.radius
    shape = (16 * r + 2, 14 * r + 2, 12 * r + 2)[:st.ndim]
    tiles = (t0, t1, t2)[:st.ndim]
    try:
        plan = compile_box_plan(st, shape, n, tiles, depth)
    except ValueError:
        # infeasible: apron deeper than the smallest tile
        tsz = min((shape[a] - 2 * r) // tiles[a]
                  for a in range(st.ndim) if tiles[a] > 1)
        assert depth * r > tsz
        return
    _, stats = DryRunExecutor().execute(plan)
    want = trapezoid_redundant_elements(st, shape, n, tiles, depth)
    assert stats.redundant_elements == want
    assert stats.elements_computed == plan.exact_elements + want


def test_time_depth_divides_transfer_rounds():
    """t steps per round trip: H2D/D2H op counts and bytes shrink ~1/t
    while redundancy grows — the knob the autotuner sweeps."""
    st = get_stencil("heat3d1r")
    shape, n = (66, 66, 66), 8
    stats = {}
    for t in (1, 2, 4):
        plan = compile_box_plan(st, shape, n, (2, 2), t)
        h2d = [op for op in plan.ops if isinstance(op, H2D)]
        d2h = [op for op in plan.ops if isinstance(op, D2H)]
        assert len(h2d) == len(d2h) == math.ceil(n / t) * 4
        _, s = DryRunExecutor().execute(plan)
        stats[t] = s
    assert stats[4].h2d_bytes < stats[2].h2d_bytes < stats[1].h2d_bytes
    assert stats[4].redundant_elements > stats[2].redundant_elements \
        > stats[1].redundant_elements == 0
    # d2h writes exactly the owned interiors, once per round
    interior = math.prod(s - 2 for s in shape) * 4
    for t, s in stats.items():
        assert s.d2h_bytes == math.ceil(n / t) * interior


def test_box_tb_feasibility_and_validation_errors():
    st = get_stencil("heat3d1r")
    with pytest.raises(ValueError, match="infeasible along axis"):
        compile_box_plan(st, (34, 34, 34), 4, (8, 1), 8)
    with pytest.raises(ValueError, match="over-ranks"):
        compile_box_plan(st, (34, 34, 34), 4, (2, 2, 2, 2), 1)
    with pytest.raises(ValueError, match=">= 1"):
        compile_box_plan(st, (34, 34, 34), 4, (0, 2), 1)


def test_autotune_box_ranks_tile_grid_x_time_depth():
    """The sweep compiles real plans, skips infeasible combos, ranks by
    modeled time, and reports redundancy that matches the closed form."""
    st = get_stencil("heat3d1r")
    shape, n = (66, 66, 66), 8
    ranked = autotune_box(
        st, shape, n, RTX3080_PAPER,
        tile_grid=((1, 1, 1), (2, 2), (2, 2, 2), (16, 16)),
        time_depth_grid=(1, 2, 4, 64))
    assert ranked
    times = [c.time_s for c in ranked]
    assert times == sorted(times)
    combos = {(c.tiles, c.time_depth) for c in ranked}
    # t=64 never fits a 64-cell interior tiled 2x; 16x16 tiles only
    # admit shallow depths (4-cell tiles, r=1 -> t <= 4)
    assert all(t != 64 or tiles == (1, 1, 1) for tiles, t in combos)
    assert ((16, 16), 4) in combos and ((16, 16), 1) in combos
    for c in ranked:
        assert c.redundant_elements == trapezoid_redundant_elements(
            st, shape, n, c.tiles, c.time_depth)
        assert c.bottleneck in ("transfer", "kernel")
    # deeper blocking must help the modeled time when transfers dominate:
    # every config here is transfer-bound, so for a fixed tile grid the
    # t=4 plan beats t=1
    by = {(c.tiles, c.time_depth): c for c in ranked}
    assert by[((2, 2), 4)].time_s < by[((2, 2), 1)].time_s


def test_run_cli_rejects_bad_geometry_flags():
    """Unknown/incompatible --chunk-axis/--tile/--time-depth exit 2."""
    from benchmarks.run import main

    for argv in (
        ["--tile", "2,2"],                          # geometry without --dry-run
        ["--time-depth", "2"],
        ["--chunk-axis", "1"],
        ["--dry-run", "--chunk-axis", "2"],         # not a 2-D axis
        ["--dry-run", "--tile", "nope"],            # malformed
        ["--dry-run", "--tile", "2,2,2,2"],         # over-ranks the domain
        ["--dry-run", "--tile", "0,2"],
        ["--dry-run", "--time-depth", "0"],
        ["--dry-run", "--time-depth", "9999"],      # apron deeper than a tile
        ["--dry-run", "--chunk-axis", "1", "--tile", "2,2"],
    ):
        with pytest.raises(SystemExit) as exc:
            main(argv)
        assert exc.value.code == 2, argv
