"""Training-loop behaviour: loss decreases, microbatch equivalence,
gradient compression, optimizer math."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataSpec, SyntheticLM
from repro.models.api import build_model
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer, compress_grads


def test_loss_decreases():
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4))
    opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=30)
    tr = Trainer(model, opt, TrainConfig(steps=30, log_every=1000))
    _, _, losses = tr.run(jax.random.PRNGKey(0), data)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]


def test_microbatch_equivalence():
    """4-way grad accumulation == single big batch (same data, fp32-close)."""
    cfg = get_smoke_config("mamba2-130m")
    model = build_model(cfg)
    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=16, global_batch=8))
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=2, clip_norm=None)

    def one(microbatches):
        tr = Trainer(model, opt, TrainConfig(steps=1, microbatches=microbatches,
                                             log_every=1000), donate=False)
        p, _, _ = tr.run(jax.random.PRNGKey(0), data)
        return p

    p1, p4 = one(1), one(4)
    # grads agree to fp roundoff, but Adam's sqrt(v)-normalization can
    # amplify roundoff on near-zero-gradient params to ~lr-scale: bound
    # by a few per-mille of the lr-sized update instead of exact equality
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=0, atol=3e-3)


def test_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)) * 1e-3,
                          jnp.float32)}
    r = {"w": jnp.zeros((64, 64), jnp.float32)}
    total_sent = jnp.zeros((64, 64), jnp.float32)
    # error feedback: accumulated quantized stream converges to the truth
    for _ in range(20):
        q, r = compress_grads(g, r, "int8")
        total_sent = total_sent + q["w"]
    expect = 20 * g["w"]
    err = float(jnp.abs(total_sent - expect).max()) / float(jnp.abs(expect).max())
    assert err < 0.05


def test_grad_compression_training_still_converges():
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=4))
    opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=20)
    tr = Trainer(model, opt, TrainConfig(steps=20, log_every=1000,
                                         grad_compression="bf16"))
    _, _, losses = tr.run(jax.random.PRNGKey(0), data)
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_adamw_matches_reference_step():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=None, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = opt.init(p)
    p1, st1 = opt.update(g, st, p)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    lr1 = float(opt.schedule(jnp.asarray(1)))
    expect = np.asarray(p["w"]) - lr1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-5)


def test_straggler_watchdog_records():
    tr = Trainer.__new__(Trainer)  # no jit build needed
    tr.straggler_events = []
    # unit-level: the EWMA logic lives in run(); here we just assert the
    # attribute contract used by launch/train.py
    assert tr.straggler_events == []
