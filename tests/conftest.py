"""Test-session setup.

Falls back to the deterministic in-tree hypothesis stub when the real
package (declared in pyproject.toml's ``test`` extra) is not installed,
so the property tests stay runnable on minimal containers.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_stub import install

    install()
