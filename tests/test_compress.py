"""Transfer-codec subsystem: exact round trips, ratio models, and the
compression rewrite pass over the plan IR.

The property tests (hypothesis; deterministic in-tree stub on minimal
containers) pin the PR's two invariants: for every engine x codec the
dry-run TransferStats equal the eager-measured stats field for field,
and lossless codecs round-trip bit-exactly — both at the array level
(encode/decode) and end-to-end (compressed plan output identical to the
uncompressed plan's).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hs

from repro.core.compress import CODECS, compress_plan, get_codec
from repro.core.executor import DoubleBufferedExecutor, DryRunExecutor, EagerExecutor
from repro.core.oocore import ENGINES, compile_plan
from repro.core.plan import Compress, D2H, Decompress, H2D
from repro.core.reference import run_reference
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil

RNG = np.random.default_rng(23)

LOSSLESS = sorted(name for name, c in CODECS.items() if c.lossless)
LOSSY = sorted(name for name, c in CODECS.items() if not c.lossless)

# bit patterns a sloppy codec gets wrong: signed zeros, denormals,
# infinities, NaN payloads, and exact-zero rows (zrle's favourite food)
ADVERSARIAL = np.array(
    [
        [0.0, -0.0, 1e-45, -1e-45, 1.0, -1.0],
        [np.inf, -np.inf, np.nan, 3.3e38, -3.3e38, 0.0],
        [0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        [np.pi, np.e, 2.0**-126, -(2.0**-126), 65504.0, -2.5],
    ],
    dtype=np.float32,
)


def _domain(st, rows=48, cols=20, seed=0):
    Y, X = rows + 2 * st.radius, cols + 2 * st.radius
    return np.random.default_rng(seed).standard_normal((Y, X)).astype(np.float32)


def _compiled(engine, st, x, n, d, k_off, k_on, codec=None):
    d_eff = 1 if engine == "incore" else d
    return compile_plan(engine, st, x.shape[0], x.shape[1], n, d_eff,
                        k_off, k_on, codec=codec)


# ---------------------------------------------------------------- codecs


def test_registry_rejects_unknown_codec():
    with pytest.raises(KeyError, match="snappy"):
        get_codec("snappy")


def test_registry_contains_required_codecs():
    assert {"identity", "bf16", "zrle"} <= set(CODECS)


@pytest.mark.parametrize("name", LOSSLESS)
def test_lossless_roundtrip_is_bit_exact(name):
    codec = CODECS[name]
    for arr in (ADVERSARIAL, RNG.standard_normal((17, 9)).astype(np.float32)):
        out = codec.decode(codec.encode(arr), arr.shape, arr.dtype)
        np.testing.assert_array_equal(
            arr.view(np.uint32), out.view(np.uint32), err_msg=name)


def test_bf16_error_is_bounded_and_idempotent():
    codec = CODECS["bf16"]
    x = RNG.standard_normal((31, 13)).astype(np.float32) * 1e3
    y = codec.decode(codec.encode(x), x.shape, x.dtype)
    rel = np.abs(y - x) / np.maximum(np.abs(x), np.finfo(np.float32).tiny)
    assert rel.max() <= codec.max_rel_error
    # re-encoding a decoded array must be lossless (repeated halo trips)
    z = codec.decode(codec.encode(y), y.shape, y.dtype)
    np.testing.assert_array_equal(y.view(np.uint32), z.view(np.uint32))


def test_bf16_preserves_specials():
    codec = CODECS["bf16"]
    y = codec.decode(codec.encode(ADVERSARIAL), ADVERSARIAL.shape, np.float32)
    assert np.isnan(y[1, 2])
    assert y[1, 0] == np.inf and y[1, 1] == -np.inf
    assert np.array_equal(np.signbit(y[0, :2]), [False, True])


def test_wire_models():
    raw = 4 * 8 * 1000
    assert CODECS["identity"].wire_nbytes(raw, 4) == raw
    assert CODECS["bf16"].wire_nbytes(raw, 4) == raw // 2
    assert 0 < CODECS["zrle"].wire_nbytes(raw, 4) < raw


def test_zrle_compresses_smooth_halo_bands():
    """The measured payload (not just the model) must shrink on the data
    zrle is tuned for: bands that are constant or smooth along rows."""
    codec = CODECS["zrle"]
    band = np.tile(np.linspace(-1, 1, 64, dtype=np.float32), (32, 1))
    assert codec.encode(band).nbytes < band.nbytes / 4


# ---------------------------------------------------- rewrite pass / IR


def test_compress_plan_rejects_already_compressed_plan():
    """Nesting codecs would double-count wire bytes and break the
    executor's Compress/Decompress pairing — the rewrite must refuse."""
    st = get_stencil("box2d1r")
    x = _domain(st)
    plan = _compiled("so2dr", st, x, 8, 4, 4, 2, codec="bf16")
    with pytest.raises(ValueError, match="already compressed"):
        compress_plan(plan, "zrle")


def test_compress_plan_rejects_incompatible_itemsize():
    """A codec the executors could not run must be rejected at rewrite
    time, so dry-run/autotune never cost an unexecutable schedule."""
    st = get_stencil("box2d1r")
    base = compile_plan("so2dr", st, 66, 66, 4, 4, 2, 2, itemsize=8)
    for codec in ("bf16", "zrle"):
        with pytest.raises(ValueError, match="itemsize"):
            compress_plan(base, codec)
    assert compress_plan(base, "identity").codec == "identity"


def test_compress_plan_wraps_every_transfer():
    st = get_stencil("box2d1r")
    x = _domain(st)
    base = _compiled("so2dr", st, x, 8, 4, 4, 2)
    plan = compress_plan(base, "bf16")
    assert plan.codec == "bf16"
    ops = list(plan.ops)
    n_xfer = sum(isinstance(op, (H2D, D2H)) for op in base.ops)
    assert sum(isinstance(op, Compress) for op in ops) == n_xfer
    assert sum(isinstance(op, Decompress) for op in ops) == n_xfer
    for i, op in enumerate(ops):
        if isinstance(op, (H2D, D2H)):
            before, after = ops[i - 1], ops[i + 1]
            assert isinstance(before, Compress) and isinstance(after, Decompress)
            assert before.raw_nbytes == op.nbytes == after.raw_nbytes
            assert before.wire_nbytes == op.nbytes // 2
            assert before.box == op.box == after.box
    s, s0 = plan.stats(), base.stats()
    assert (s.h2d_bytes, s.d2h_bytes) == (s0.h2d_bytes, s0.d2h_bytes)
    assert s.wire_bytes * 2 == s.transfer_bytes
    assert s0.wire_bytes == s0.transfer_bytes  # uncompressed: wire == raw


# ------------------------------------------- property: engines x codecs


@settings(max_examples=20, deadline=None)
@given(
    engine=hs.sampled_from(sorted(ENGINES)),
    codec=hs.sampled_from(sorted(CODECS)),
    stencil=hs.sampled_from(sorted(PAPER_BENCHMARKS)),
    n=hs.integers(2, 6),
    k_off=hs.integers(1, 4),
    k_on=hs.integers(1, 3),
    seed=hs.integers(0, 2**16),
)
def test_dry_run_stats_equal_eager_stats_for_every_engine_codec(
        engine, codec, stencil, n, k_off, k_on, seed):
    """Accounting is a property of the plan: eager execution of a
    compressed schedule must report exactly the stats the zero-device
    dry run predicted, and wire bytes must undercut raw bytes for every
    non-identity codec."""
    st = get_stencil(stencil)
    x = _domain(st, seed=seed)
    try:
        plan = _compiled(engine, st, x, n, 4, k_off, k_on, codec=codec)
    except ValueError:
        return  # infeasible k_off for this geometry: planner rejected it
    _, dry = DryRunExecutor().execute(plan)
    _, eager = EagerExecutor().execute(plan, x)
    for f in dataclasses.fields(eager):
        assert getattr(dry, f.name) == getattr(eager, f.name), f.name
    assert dry.codec_ops > 0
    if codec == "identity":
        assert dry.wire_bytes == dry.transfer_bytes
    else:
        assert dry.wire_bytes < dry.transfer_bytes


@settings(max_examples=10, deadline=None)
@given(
    engine=hs.sampled_from(sorted(ENGINES)),
    codec=hs.sampled_from(LOSSLESS),
    seed=hs.integers(0, 2**16),
)
def test_lossless_codecs_roundtrip_bit_exactly_through_executors(
        engine, codec, seed):
    """A lossless codec must be invisible to the computation: the
    compressed plan's eager output is bitwise identical to the
    uncompressed plan's, on both device executors."""
    st = get_stencil("box2d2r")
    x = _domain(st, seed=seed)
    base = _compiled(engine, st, x, 6, 4, 3, 2)
    plan = compress_plan(base, codec)
    out0, _ = EagerExecutor().execute(base, x)
    out1, _ = EagerExecutor().execute(plan, x)
    out2, _ = DoubleBufferedExecutor().execute(plan, x)
    np.testing.assert_array_equal(out0.view(np.uint32), out1.view(np.uint32))
    np.testing.assert_array_equal(out1.view(np.uint32), out2.view(np.uint32))


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_bf16_compressed_execution_has_bounded_error(engine):
    """Lossy transfers re-quantize each round trip; over all rounds the
    result must stay within a small multiple of the per-trip bound."""
    st = get_stencil("box2d1r")
    x = _domain(st)
    n = 8
    plan = _compiled(engine, st, x, n, 4, 4, 2, codec="bf16")
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    out, stats = EagerExecutor().execute(plan, x)
    scale = np.abs(ref).max()
    assert np.abs(out - ref).max() / scale < 0.02
    assert stats.wire_bytes * 2 == stats.transfer_bytes


def test_compressed_double_buffered_prefetch_matches_eager():
    """Prefetching the next chunk's Compress+H2D under the current
    chunk's kernels must not change results (bf16 is deterministic, so
    even the lossy codec must agree bitwise between executors)."""
    st = get_stencil("box2d3r")
    x = _domain(st)
    plan = _compiled("so2dr", st, x, 8, 4, 4, 2, codec="bf16")
    out_eager, _ = EagerExecutor().execute(plan, x)
    out_db, _ = DoubleBufferedExecutor().execute(plan, x)
    np.testing.assert_array_equal(
        out_eager.view(np.uint32), out_db.view(np.uint32))
