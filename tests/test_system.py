"""End-to-end behaviour tests for the paper's system.

The out-of-core SO2DR pipeline, driven by the real Pallas kernel, beats
ResReu on the paper's own cost axes (kernel launches, O/D transactions)
while matching the oracle bit-for-bit on the final state — the paper's
central claim, checked end to end.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.analytic import TPU_V5E, model_times
from repro.core.oocore import ResReu, SO2DR
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil


def test_so2dr_end_to_end_beats_resreu_on_model():
    """Run both engines on the same workload; the Sec. III model with TPU
    constants must reproduce the paper's headline (SO2DR faster than
    ResReu when kernels dominate)."""
    st = get_stencil("box2d1r")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((130, 130)).astype(np.float32)
    n, d, k_off, k_on = 16, 4, 8, 4

    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    out_so, s_so = SO2DR(d=d, k_off=k_off, k_on=k_on).run(x, st, n)
    out_rr, s_rr = ResReu(d=d, k_off=k_off, k_on=k_on).run(x, st, n)

    scale = np.abs(ref).max()
    assert np.abs(out_so - ref).max() / scale < 1e-5
    assert np.abs(out_rr - ref).max() / scale < 1e-5

    t_so = model_times(s_so, TPU_V5E)
    t_rr = model_times(s_rr, TPU_V5E)
    # same transfer volume (region sharing preserved) ...
    assert s_so.h2d_bytes == s_rr.h2d_bytes
    # ... but fewer kernel launches and a faster modeled total
    assert s_so.kernel_calls * k_on <= s_rr.kernel_calls
    assert t_so.total_overlapped() <= t_rr.total_overlapped()


def test_full_pipeline_with_pallas_kernel():
    from repro.kernels.ops import kernel_fused_step

    st = get_stencil("gradient2d")
    rng = np.random.default_rng(1)
    x = rng.standard_normal((66, 66)).astype(np.float32)
    n = 8
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    out, stats = SO2DR(d=2, k_off=4, k_on=2,
                       fused_step=kernel_fused_step).run(x, st, n)
    assert np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9) < 1e-5
    assert stats.kernel_calls == 2 * 2 * 2  # d * rounds * (k_off/k_on)


def test_tiny_lm_end_to_end():
    """Train a tiny LM for 12 steps, then serve 4 tokens greedily."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data import DataSpec, SyntheticLM
    from repro.models.api import build_model
    from repro.optim import AdamW
    from repro.serve.decode import greedy_generate
    from repro.train import TrainConfig, Trainer

    cfg = get_smoke_config("mamba2-130m")
    model = build_model(cfg)
    data = SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=32, global_batch=2))
    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=12)
    tr = Trainer(model, opt, TrainConfig(steps=12, log_every=1000))
    params, _, losses = tr.run(jax.random.PRNGKey(0), data)
    assert np.isfinite(losses).all()

    batch = {k: jnp.asarray(v) for k, v in data.batch(99).items()}
    toks = greedy_generate(model, params, batch, max_new=4, max_len=40)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab).all()
