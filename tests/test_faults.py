"""Fault tolerance: deterministic injection, checkpoint/resume, elastic
re-planning, service degradation.

The load-bearing property (the crash matrix): a terminal fault injected
at *any* round of *any* engine x executor x codec, recovered through
``run_with_recovery`` + ``PlanCheckpointer``, produces a host array
bit-identical to the uninterrupted run — ``HostCommit`` barriers are
exact recovery points because registers and buffers never cross one.
Everything runs with zero devices except one 8-fake-device subprocess
case exercising rank loss on the real ``shard_map`` backend.
"""
import numpy as np
import pytest

from _subproc import run_fake_device_subprocess

from repro.core.executor import (
    DoubleBufferedExecutor, EagerExecutor, ShardedSimExecutor,
)
from repro.core.faults import (
    KERNEL_FAULT, RANK_LOSS, SLOT_EXHAUSTED, TRANSIENT_TRANSFER,
    FaultPlan, FaultTrigger, RetryPolicy, TransientTransferError,
)
from repro.core.lower import SlotPool, lower
from repro.core.oocore import compile_box_plan, compile_plan
from repro.core.recovery import (
    PlanCheckpointer, PlanExecutionError, plan_fingerprint, resume_plan,
    run_with_recovery,
)
from repro.core.shard import compile_sharded
from repro.core.stencil import get_stencil
from repro.checkpoint import CheckpointManager
from repro.launch.elastic import (
    ElasticReport, replan_sharded, run_elastic_sharded, shrink_mesh,
)
from repro.serve import StencilJob, StencilService

RNG = np.random.default_rng(11)
NO_WAIT = RetryPolicy(sleep=lambda s: None)


def _domain(Y=32, X=16):
    return RNG.standard_normal((Y, X)).astype(np.float32)


def _plan(engine="so2dr", codec=None, Y=32, X=16, n=8, d=2, k_off=4,
          k_on=2):
    st = get_stencil("star2d1r")
    if engine == "box_tb":
        return compile_box_plan(st, (Y, X), n, (2, 1), k_off, k_on,
                                codec=codec)
    return compile_plan(engine, st, Y, X, n, d, k_off, k_on, codec=codec)


def _rounds(plan):
    return sorted({op.round for op in plan.ops})


def _make_executor(name):
    return {"eager": EagerExecutor,
            "double_buffered": DoubleBufferedExecutor}[name]()


# ------------------------------------------------------- crash matrix


@pytest.mark.parametrize("executor", ["eager", "double_buffered"])
@pytest.mark.parametrize(
    "engine", ["incore", "naive_tb", "resreu", "so2dr", "box_tb"])
def test_crash_at_every_round_resumes_bit_identical(
        engine, executor, tmp_path):
    """Terminal kernel fault at each round -> checkpointed resume ->
    bit-identical to the uninterrupted run (every engine x executor)."""
    plan = _plan(engine)
    x = _domain()
    ref, _ = EagerExecutor().execute(plan, x)
    for rnd in _rounds(plan):
        mgr = CheckpointManager(str(tmp_path / f"{engine}_{rnd}"))
        faults = FaultPlan([FaultTrigger(round=rnd, chunk=None,
                                         op_class="*", kind=KERNEL_FAULT)])
        ex = _make_executor(executor)
        host, _ = run_with_recovery(
            plan, x, executor=ex, faults=faults,
            checkpoint=PlanCheckpointer(mgr, plan))
        np.testing.assert_array_equal(host, ref), (engine, executor, rnd)
        assert ex.exec_stats.resumes == 1
        assert ex.exec_stats.faults_injected == 1


@pytest.mark.parametrize("executor", ["eager", "double_buffered"])
def test_crash_matrix_with_compression_codec(executor, tmp_path):
    """The resume property holds through the zrle transfer codec —
    Compress/Decompress ops carry rounds like every other op."""
    plan = _plan("so2dr", codec="zrle")
    x = _domain()
    ref, _ = EagerExecutor().execute(plan, x)
    for rnd in _rounds(plan):
        mgr = CheckpointManager(str(tmp_path / f"zrle_{rnd}"))
        faults = FaultPlan([FaultTrigger(round=rnd, chunk=None,
                                         op_class="*", kind=KERNEL_FAULT)])
        ex = _make_executor(executor)
        host, _ = run_with_recovery(
            plan, x, executor=ex, faults=faults,
            checkpoint=PlanCheckpointer(mgr, plan))
        np.testing.assert_array_equal(host, ref)
        assert ex.exec_stats.resumes == 1


def test_sharded_sim_crash_and_recovery(tmp_path):
    """A sharded plan dies typed (it commits host state only at the
    end, so last_committed_round=-1) and run_with_recovery restarts it
    from scratch to the bit-identical answer."""
    plan = compile_sharded(get_stencil("star2d1r"), 48, 32, 8, 2, (4, 2))
    x = RNG.standard_normal((48, 32)).astype(np.float32)
    ref, _ = ShardedSimExecutor().execute(plan, x)
    faults = FaultPlan([FaultTrigger(round=2, chunk=5, op_class="*",
                                     kind=KERNEL_FAULT)])
    with pytest.raises(PlanExecutionError) as ei:
        ShardedSimExecutor().execute(plan, x, injector=faults.injector())
    assert ei.value.last_committed_round == -1
    ex = ShardedSimExecutor()
    mgr = CheckpointManager(str(tmp_path))
    host, _ = run_with_recovery(plan, x, executor=ex, faults=faults,
                                checkpoint=PlanCheckpointer(mgr, plan))
    np.testing.assert_array_equal(host, ref)
    assert ex.exec_stats.resumes == 1


# ------------------------------------------- injection + retry mechanics


def test_seeded_fault_plans_are_deterministic():
    plan = _plan()
    a = FaultPlan.seeded(17, plan, n_faults=4,
                         kinds=(TRANSIENT_TRANSFER, KERNEL_FAULT),
                         op_classes=("H2D", "FusedKernel"))
    b = FaultPlan.seeded(17, plan, n_faults=4,
                         kinds=(TRANSIENT_TRANSFER, KERNEL_FAULT),
                         op_classes=("H2D", "FusedKernel"))
    assert a.triggers == b.triggers
    keys = {k for k, _ in plan.stages() if k is not None}
    for t in a.triggers:                 # sites drawn from real geometry
        assert (t.round, t.chunk) in keys
    c = FaultPlan.seeded(18, plan, n_faults=4,
                         kinds=(TRANSIENT_TRANSFER, KERNEL_FAULT),
                         op_classes=("H2D", "FusedKernel"))
    assert a.triggers != c.triggers      # seed actually matters


def test_transient_fault_absorbed_by_retry():
    """A transient trigger with count <= max_retries never surfaces:
    the stage loop retries in place and the output stays bitwise."""
    plan = _plan()
    x = _domain()
    ref, _ = EagerExecutor().execute(plan, x)
    faults = FaultPlan([FaultTrigger(round=0, chunk=0, op_class="H2D",
                                     kind=TRANSIENT_TRANSFER, count=2)])
    ex = EagerExecutor()
    host, _ = run_with_recovery(plan, x, executor=ex, faults=faults,
                                retry=NO_WAIT)
    np.testing.assert_array_equal(host, ref)
    assert ex.exec_stats.faults_injected == 2
    assert ex.exec_stats.retries == 2
    assert ex.exec_stats.resumes == 0


def test_retry_exhaustion_surfaces_typed_error():
    """A transient fault persisting past the retry budget becomes a
    terminal PlanExecutionError carrying the transient cause."""
    plan = _plan()
    faults = FaultPlan([FaultTrigger(round=0, chunk=0, op_class="H2D",
                                     kind=TRANSIENT_TRANSFER, count=10)])
    injector = faults.injector()
    with pytest.raises(PlanExecutionError) as ei:
        run_with_recovery(plan, _domain(), faults=injector, retry=NO_WAIT)
    assert isinstance(ei.value.fault, TransientTransferError)
    assert ei.value.last_committed_round == -1
    assert injector.retries == NO_WAIT.max_retries
    assert injector.faults_injected == NO_WAIT.max_retries + 1


def test_clean_run_with_injector_is_invisible():
    """An armed injector whose triggers never fire changes nothing:
    zero fault counters, bit-identical output."""
    plan = _plan()
    x = _domain()
    ref, _ = EagerExecutor().execute(plan, x)
    ex = EagerExecutor()
    host, _ = ex.execute(plan, x, injector=FaultPlan([]).injector())
    np.testing.assert_array_equal(host, ref)
    assert ex.exec_stats.faults_injected == 0
    assert ex.exec_stats.retries == 0


def test_legacy_executor_path_rejects_hooks():
    with pytest.raises(ValueError, match="lowered"):
        EagerExecutor(lowered=False).execute(
            _plan(), _domain(), injector=FaultPlan([]).injector())


# ----------------------------------------------------- slot-lease leaks


def test_slot_pool_drains_after_faulted_run():
    """The slot-lease leak regression: a run killed mid-stage still
    returns every leased slot to the pool (try/finally in execute)."""
    pool = SlotPool()
    plan = _plan()
    compiled = lower(plan)
    faults = FaultPlan([FaultTrigger(round=1, chunk=0, op_class="*",
                                     kind=SLOT_EXHAUSTED)])
    with pytest.raises(PlanExecutionError):
        compiled.execute(_domain(), slot_pool=pool,
                         injector=faults.injector())
    assert pool.in_use == 0
    assert pool.leases == 1
    compiled.execute(_domain(), slot_pool=pool)       # pool still healthy
    assert pool.in_use == 0 and pool.reuses == 1


# ------------------------------------------------- resume-plan algebra


def test_resume_plan_structure():
    plan = _plan()
    assert resume_plan(plan, 0) is plan
    cont = resume_plan(plan, 1)
    assert min(op.round for op in cont.ops) == 1
    assert cont.exact_elements == plan.exact_elements // 2  # half the steps
    assert plan_fingerprint(cont) != plan_fingerprint(plan)


def test_checkpointer_ignores_foreign_fingerprints(tmp_path):
    """A snapshot taken under one plan is never resumed into another."""
    mgr = CheckpointManager(str(tmp_path))
    plan_a, plan_b = _plan("so2dr"), _plan("resreu")
    ck_a = PlanCheckpointer(mgr, plan_a)
    ck_a.on_commit(0, _domain())
    assert ck_a.latest() is not None
    assert PlanCheckpointer(mgr, plan_b).latest() is None


def test_checkpoint_cadence(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10)
    ck = PlanCheckpointer(mgr, _plan(), every=2)
    for rnd in range(4):
        ck.on_commit(rnd, _domain())
    assert ck.saves == 2                       # rounds 0 and 2 only
    rnd, _ = ck.latest()
    assert rnd == 2


# --------------------------------------------------- elastic re-planning


def test_elastic_rank_loss_replans_within_one_round():
    """Rank loss on a (4,2) mesh: re-plan to (3,2) on the survivors,
    finish within exactly one extra round of transfers, match the
    fault-free answer."""
    plan = compile_sharded(get_stencil("star2d1r"), 48, 32, 8, 2, (4, 2))
    x = RNG.standard_normal((48, 32)).astype(np.float32)
    ref, _ = ShardedSimExecutor().execute(plan, x)

    out, rep = run_elastic_sharded(plan, x)    # fault-free: bitwise
    np.testing.assert_array_equal(out, ref)
    assert rep.extra_rounds == 0 and rep.replans == 0

    faults = FaultPlan([FaultTrigger(round=1, chunk=3, op_class="*",
                                     kind=RANK_LOSS)])
    out, rep = run_elastic_sharded(plan, x, faults=faults)
    assert isinstance(rep, ElasticReport)
    assert rep.replans == 1 and rep.extra_rounds == 1
    assert rep.mesh_history == ((4, 2), (3, 2))
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_elastic_survives_successive_preemptions():
    plan = compile_sharded(get_stencil("star2d1r"), 48, 32, 8, 2, (4, 2))
    x = RNG.standard_normal((48, 32)).astype(np.float32)
    ref, _ = ShardedSimExecutor().execute(plan, x)
    faults = FaultPlan([
        FaultTrigger(round=0, chunk=0, op_class="*", kind=RANK_LOSS),
        FaultTrigger(round=2, chunk=1, op_class="*", kind=RANK_LOSS)])
    out, rep = run_elastic_sharded(plan, x, faults=faults)
    assert rep.mesh_history == ((4, 2), (3, 2), (2, 2))
    assert rep.extra_rounds == 2
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_elastic_terminal_fault_and_mesh_algebra():
    plan = compile_sharded(get_stencil("star2d1r"), 48, 32, 8, 2, (4, 2))
    x = RNG.standard_normal((48, 32)).astype(np.float32)
    faults = FaultPlan([FaultTrigger(round=2, chunk=None, op_class="*",
                                     kind=KERNEL_FAULT)])
    with pytest.raises(PlanExecutionError) as ei:
        run_elastic_sharded(plan, x, faults=faults)
    assert ei.value.last_committed_round == 1  # rounds 0-1 stored

    assert shrink_mesh((4, 2), 7) == (3, 2)
    assert shrink_mesh((1, 4), 0) == (1, 3)
    with pytest.raises(ValueError):
        shrink_mesh((1, 1), 0)
    cont = replan_sharded(plan, 2)
    assert cont.rounds == 2 and cont.mesh_shape == (4, 2)
    with pytest.raises(ValueError):
        replan_sharded(plan, plan.rounds)      # nothing left to do


_ELASTIC_SUBPROC = r"""
import numpy as np
from repro.core.executor import ShardMapExecutor, ShardedSimExecutor
from repro.core.faults import FaultPlan, FaultTrigger, RANK_LOSS
from repro.core.shard import compile_sharded
from repro.core.stencil import get_stencil
from repro.launch.elastic import run_elastic_sharded

plan = compile_sharded(get_stencil("box2d1r"), 48, 32, 8, 2, (4, 2))
x = np.random.default_rng(3).standard_normal((48, 32)).astype(np.float32)
ref, _ = ShardedSimExecutor().execute(plan, x)
faults = FaultPlan([FaultTrigger(round=1, chunk=6, op_class="*",
                                 kind=RANK_LOSS)])
out, rep = run_elastic_sharded(
    plan, x, faults=faults,
    executor_factory=lambda mesh_shape: ShardMapExecutor())
assert rep.replans == 1 and rep.extra_rounds == 1, rep
assert rep.mesh_history == ((4, 2), (3, 2)), rep
assert np.abs(out - ref).max() < 1e-5
print("ELASTIC_SHARD_MAP_OK")
"""


def test_elastic_rank_loss_on_shard_map_backend_subprocess():
    """8 fake devices: the same preemption story through the real
    shard_map backend — injection is probed per rank before dispatch
    (one fused program is all-or-nothing), the re-planned (3,2) mesh
    uses 6 of the 8 devices."""
    run_fake_device_subprocess(_ELASTIC_SUBPROC, "ELASTIC_SHARD_MAP_OK")


# -------------------------------------------------- service degradation


def test_service_isolates_failed_job():
    """One poisoned job in a flush batch: it comes back failed with the
    typed fault, every survivor is bit-identical to its solo run, and
    the slot pool fully drains."""
    x = np.arange(32 * 16, dtype=np.float32).reshape(32, 16) / 7.0
    faults = FaultPlan([FaultTrigger(round=1, chunk=0, op_class="*",
                                     kind=KERNEL_FAULT)])

    ref_svc = StencilService()
    for _ in range(3):
        ref_svc.submit(StencilJob(shape=(32, 16), stencil="star2d1r",
                                  steps=8, s_tb=4), x)
    ref = {r.job_id: r.out for r in ref_svc.flush()}

    svc = StencilService()
    for i in range(3):
        svc.submit(StencilJob(shape=(32, 16), stencil="star2d1r",
                              steps=8, s_tb=4,
                              faults=faults if i == 1 else None,
                              retry=NO_WAIT), x)
    results = {r.job_id: r for r in svc.flush()}
    assert len(results) == 3
    assert results[1].status == "failed" and results[1].out is None
    assert isinstance(results[1].fault, PlanExecutionError)
    assert results[1].fault.last_committed_round == 0
    for jid in (0, 2):
        assert results[jid].status == "ok" and results[jid].fault is None
        np.testing.assert_array_equal(results[jid].out, ref[jid])
    assert svc.slot_pool.in_use == 0
    stats = svc.service_stats()
    assert stats["jobs_failed"] == 1 and stats["jobs_completed"] == 2


def test_service_transient_faults_retried_transparently():
    x = _domain()
    ref_svc = StencilService()
    ref = ref_svc.run_solo(StencilJob(shape=(32, 16), stencil="star2d1r",
                                      steps=8, s_tb=4), x)
    svc = StencilService()
    faults = FaultPlan([FaultTrigger(round=0, chunk=0, op_class="H2D",
                                     kind=TRANSIENT_TRANSFER, count=2)])
    svc.submit(StencilJob(shape=(32, 16), stencil="star2d1r", steps=8,
                          s_tb=4, faults=faults, retry=NO_WAIT), x)
    res, = svc.flush()
    assert res.status == "ok"
    assert res.exec_stats.faults_injected == 2
    assert res.exec_stats.retries == 2
    np.testing.assert_array_equal(res.out, ref.out)
