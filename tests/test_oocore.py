"""Out-of-core engines vs the oracle + TransferStats invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st_h

from repro.core.oocore import InCore, NaiveTB, ResReu, SO2DR, get_engine
from repro.core.reference import run_reference
from repro.core.stencil import get_stencil

RNG = np.random.default_rng(3)


def _domain(st, rows=60, cols=44):
    Y, X = rows + 2 * st.radius, cols + 2 * st.radius
    return RNG.standard_normal((Y, X)).astype(np.float32)


@pytest.mark.parametrize("name", ["box2d1r", "box2d2r", "gradient2d"])
@pytest.mark.parametrize("engine", ["incore", "naive_tb", "resreu", "so2dr"])
def test_engine_matches_oracle(name, engine):
    st = get_stencil(name)
    x = _domain(st)
    n = 10
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    eng = get_engine(engine, d=4, k_off=4, k_on=3)
    out, _ = eng.run(x, st, n)
    scale = np.abs(ref).max() + 1e-6
    assert np.abs(out - ref).max() / scale < 1e-5


def test_transfer_stats_invariants():
    st = get_stencil("box2d2r")
    x = _domain(st)
    n, d, k = 8, 4, 4
    _, s_naive = NaiveTB(d=d, k_off=k, k_on=2).run(x, st, n)
    _, s_res = ResReu(d=d, k_off=k, k_on=2).run(x, st, n)
    _, s_so = SO2DR(d=d, k_off=k, k_on=2).run(x, st, n)
    _, s_inc = InCore(d=1, k_off=k, k_on=2).run(x, st, n)

    # region sharing eliminates redundant transfer
    assert s_so.h2d_bytes == s_res.h2d_bytes
    assert s_naive.h2d_bytes > s_so.h2d_bytes
    # ResReu: zero redundant compute; SO2DR: deliberate redundancy
    assert s_res.redundant_elements == 0
    assert s_so.redundant_elements > 0
    assert s_naive.redundant_elements == s_so.redundant_elements
    # SO2DR needs far fewer kernel launches (k_on-fused, uninterrupted)
    assert s_so.kernel_calls < s_res.kernel_calls
    # in-core: one transfer each way
    assert s_inc.h2d_bytes == x.nbytes and s_inc.d2h_bytes == x.nbytes
    # everyone does the same useful work
    assert s_res.exact_elements == s_so.exact_elements == s_naive.exact_elements


def test_resreu_paper_shares_two_regions_per_step():
    """The paper (Fig. 2b): two r-row regions read + two written per step."""
    st = get_stencil("box2d1r")
    x = _domain(st)
    n, d, k = 4, 4, 4
    _, s = ResReu(d=d, k_off=k, k_on=1).run(x, st, n)
    X = x.shape[1]
    r = st.radius
    per_step = 2 * r * X * 4  # bytes of one shared-region pair
    # chunks 0..d-2 write, chunks 1..d-1 read, k steps per round, 1 round
    expect = per_step * k * (d - 1) * 2
    assert s.buffer_bytes == expect


def test_so2dr_redundancy_is_k_squared():
    """Redundant rows per interior boundary per round = r*k(k-1)."""
    st = get_stencil("box2d1r")
    x = _domain(st, rows=64)
    d = 2
    for k in (2, 4):
        _, s = SO2DR(d=d, k_off=k, k_on=1).run(x, st, k)  # one round
        X_int = x.shape[1] - 2 * st.radius
        expect = st.radius * k * (k - 1) * X_int * (d - 1)
        assert s.redundant_elements == expect, (k, s.redundant_elements, expect)


def test_k_off_feasibility_validated():
    st = get_stencil("box2d4r")
    x = _domain(st, rows=32)  # chunks of 8 rows, r=4 -> max k_off = 2
    with pytest.raises(ValueError):
        SO2DR(d=4, k_off=3, k_on=1).run(x, st, 3)


@settings(max_examples=12, deadline=None)
@given(
    name=st_h.sampled_from(["box2d1r", "box2d2r", "gradient2d"]),
    n=st_h.integers(1, 9),
    d=st_h.integers(1, 5),
    k_off=st_h.integers(1, 5),
    k_on=st_h.integers(1, 5),
    rows=st_h.integers(40, 80),
)
def test_engines_property(name, n, d, k_off, k_on, rows):
    st = get_stencil(name)
    x = _domain(st, rows=rows)
    min_chunk = (rows // d) if d else rows
    if k_off * st.radius > min_chunk or min_chunk < 2 * st.radius:
        return
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    scale = np.abs(ref).max() + 1e-6
    for engine in ("so2dr", "resreu"):
        out, _ = get_engine(engine, d=d, k_off=k_off, k_on=k_on).run(x, st, n)
        assert np.abs(out - ref).max() / scale < 1e-5, engine


def test_so2dr_with_pallas_kernel():
    """Alg. 1 driven by the actual Pallas fused kernel (interpret mode)."""
    from repro.kernels.ops import kernel_fused_step

    st = get_stencil("box2d1r")
    x = _domain(st)
    n = 6
    ref = np.asarray(run_reference(jnp.asarray(x), st, n))
    eng = SO2DR(d=2, k_off=3, k_on=3, fused_step=kernel_fused_step)
    out, _ = eng.run(x, st, n)
    scale = np.abs(ref).max() + 1e-6
    assert np.abs(out - ref).max() / scale < 1e-5
