"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For each of the 10 assigned architectures: forward shapes + finiteness,
train-step grads finite, prefill == full forward (exact), decode step
within bf16 tolerance of the full forward.
"""
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.api import build_model

def make_batch(cfg, B=2, S=32):
    # seed by a *stable* hash of the arch name: results must not depend on
    # pytest execution order OR on the process (builtin hash() is salted by
    # PYTHONHASHSEED, which made llama4-maverick's decode check flaky —
    # every run sampled a different batch)
    rng = np.random.default_rng(zlib.crc32(cfg.name.encode()))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_image_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frames, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)

    # forward: shape + finiteness
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # train step: finite loss + grads
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0

    # prefill == full forward at the last prompt position (bitwise-ish)
    cache = model.init_cache(B, S + 4)
    lg_pre, cache = model.prefill(params, batch, cache)
    ref_pre = logits[:, -1]
    e_pre = float(jnp.max(jnp.abs(
        lg_pre[:, 0].astype(jnp.float32) - ref_pre.astype(jnp.float32))))
    assert e_pre < 1e-3, e_pre

    # decode step == full forward on the extended sequence (bf16 tolerance;
    # MoE smoke configs use ample capacity so routing drops can't differ)
    nxt = jnp.argmax(lg_pre[:, -1], axis=-1)[:, None].astype(jnp.int32)
    lg_dec, cache = model.decode_step(params, nxt, jnp.int32(S), cache)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    full_logits, _ = model.forward(params, batch2)
    scale = float(jnp.max(jnp.abs(full_logits[:, S].astype(jnp.float32)))) + 1e-6
    e_dec = float(jnp.max(jnp.abs(
        lg_dec[:, 0].astype(jnp.float32) - full_logits[:, S].astype(jnp.float32))))
    assert e_dec / scale < 5e-2, (e_dec, scale)


def test_sliding_window_masks_prefix():
    """SWA: per layer, tokens beyond the window cannot influence the
    output (receptive field = n_layers * window, so test with 1 layer)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"),
                              n_layers=1)  # window 32
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 1, 48  # window 32 < S
    b1 = make_batch(cfg, B, S)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[0, 0].set((b2["tokens"][0, 0] + 1) % cfg.vocab)
    l1, _ = model.forward(params, b1)
    l2, _ = model.forward(params, b2)
    # positions > window away from position 0: identical logits
    np.testing.assert_array_equal(np.asarray(l1[0, 40:]), np.asarray(l2[0, 40:]))
    # an early position (within the window of pos 0) must differ
    assert not np.array_equal(np.asarray(l1[0, 8]), np.asarray(l2[0, 8]))


def test_moe_capacity_drops_tokens():
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 0.5})
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert float(aux) > 0  # aux loss present


def test_param_counts_full_configs():
    """Analytic param counts of full configs land near the nameplate."""
    from repro.configs import get_config
    approx = {
        "minitron-4b": (4e9, 0.75),         # 4B + big embeddings
        "phi3-medium-14b": (14e9, 0.35),
        "mixtral-8x7b": (46e9, 0.3),
        "llama4-maverick-400b-a17b": (400e9, 0.3),
        "mamba2-130m": (130e6, 0.45),
        "zamba2-2.7b": (2.7e9, 0.5),
    }
    for name, (target, tol) in approx.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < tol, (name, n, target)
