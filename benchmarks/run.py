"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV.  Rows labeled ``measured_cpu``
are wall-clock on this container; ``modeled`` rows evaluate the paper's
Sec. III analytic model over exact TransferStats geometry with RTX-3080
(paper-validation) or TPU-v5e (deployment-target) constants.  The
roofline rows read the multi-pod dry-run artifacts if present.
"""
import sys


def main() -> None:
    from . import (
        autotune_bench, fig5_config_sweep, fig6_so2dr_vs_resreu,
        fig7_breakdown, fig8_single_step, fig9_incore_vs_oocore,
        kernel_micro, roofline,
    )
    from .common import emit

    print("name,us_per_call,derived")
    for mod in (fig6_so2dr_vs_resreu, fig7_breakdown, fig5_config_sweep,
                fig8_single_step, fig9_incore_vs_oocore, autotune_bench,
                kernel_micro):
        try:
            emit(mod.run())
        except Exception as e:  # keep the harness robust
            print(f"{mod.__name__},0,ERROR {e}", file=sys.stdout)
    try:
        rows = roofline.run()
        if rows:
            emit(rows)
        else:
            print("roofline,0,no dry-run artifacts (run scripts/run_dryrun_all.sh)")
    except Exception as e:
        print(f"roofline,0,ERROR {e}")


if __name__ == "__main__":
    main()
