"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --dry-run
    PYTHONPATH=src python -m benchmarks.run --dry-run --codec all --json BENCH_plan.json

Prints ``name,us_per_call,derived`` CSV.  Rows labeled ``measured_cpu``
are wall-clock on this container; ``modeled`` rows evaluate the paper's
Sec. III analytic model over exact TransferStats geometry with RTX-3080
(paper-validation) or TPU-v5e (deployment-target) constants.  The
roofline rows read the multi-pod dry-run artifacts if present.

``--dry-run`` compiles the transfer/kernel op schedule for every engine x
paper stencil at the full out-of-core size and walks it with the dry-run
executor — plan construction and plan-derived accounting are exercised
end-to-end with zero device work (the CI smoke job).  ``--codec`` sweeps
transfer codecs (``all`` = every registered codec) and reports raw vs
wire bytes; ``--json`` writes the dry-run rows as a machine-readable
``BENCH_plan.json`` for the CI bench-gate
(``benchmarks/check_regression.py`` diffs it against the committed
``benchmarks/baselines.json``).

Unknown ``--engine``/``--codec`` names are a hard error (exit code 2),
not a silent skip.
"""
import argparse
import json
import sys


def _resolve_names(requested, known, kind, parser):
    """Expand 'all' and validate names against a registry; exit 2 on
    unknown names instead of silently skipping them."""
    if requested in (None, "all"):
        return sorted(known)
    names = [s for s in requested.split(",") if s]
    for name in names:
        if name not in known:
            parser.error(
                f"unknown {kind} {name!r}; known: {sorted(known)} (or 'all')")
    return names


def dry_run(engines, codecs, json_path=None) -> None:
    from repro.core.compress import compress_plan
    from repro.core.executor import DryRunExecutor
    from repro.core.stencil import PAPER_BENCHMARKS

    from .common import OOC_SZ, PAPER_CONFIG, paper_plan

    print("name,plan_ops,derived")
    ex = DryRunExecutor()
    records = {}
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in engines:
            base = paper_plan(engine, name, OOC_SZ, d, s_tb)
            for codec in codecs:
                plan = compress_plan(base, codec)
                _, s = ex.execute(plan)
                key = f"{name}/{engine}/{codec}"
                print(f"dryrun/{key},{len(plan)},"
                      f"h2d_gb={s.h2d_bytes / 1e9:.2f} "
                      f"d2h_gb={s.d2h_bytes / 1e9:.2f} "
                      f"wire_gb={s.wire_bytes / 1e9:.2f} "
                      f"ratio={s.compression_ratio:.3f} "
                      f"odc_gb={s.buffer_bytes / 1e9:.2f} "
                      f"kernels={s.kernel_calls} "
                      f"redundancy={s.redundancy:.4f}")
                records[key] = {
                    "plan_ops": len(plan),
                    "raw_bytes": s.transfer_bytes,
                    "wire_bytes": s.wire_bytes,
                    "h2d_wire_bytes": s.h2d_wire_bytes,
                    "d2h_wire_bytes": s.d2h_wire_bytes,
                    "buffer_bytes": s.buffer_bytes,
                    "kernel_calls": s.kernel_calls,
                }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(records)} plan records to {json_path}",
              file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="compile + cost every engine's plan, no device work")
    ap.add_argument("--engine", default="all",
                    help="comma-separated engine names, or 'all' (default)")
    ap.add_argument("--codec", default="identity",
                    help="comma-separated transfer codecs, or 'all' "
                         "(default: identity — uncompressed wire bytes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write dry-run plan records as JSON (bench-gate)")
    args = ap.parse_args(argv)

    from repro.core.compress import CODECS
    from repro.core.oocore import ENGINES

    engines = _resolve_names(args.engine, ENGINES, "engine", ap)
    codecs = _resolve_names(args.codec, CODECS, "codec", ap)

    if args.dry_run:
        dry_run(engines, codecs, json_path=args.json)
        return
    if args.json or args.engine != "all" or args.codec != "identity":
        ap.error("--engine/--codec/--json only apply to --dry-run; the "
                 "measured path always runs the full figure suite")

    from . import (
        autotune_bench, fig5_config_sweep, fig6_so2dr_vs_resreu,
        fig7_breakdown, fig7_codec_breakdown, fig8_single_step,
        fig9_incore_vs_oocore, kernel_micro, roofline,
    )
    from .common import emit

    print("name,us_per_call,derived")
    for mod in (fig6_so2dr_vs_resreu, fig7_breakdown, fig7_codec_breakdown,
                fig5_config_sweep, fig8_single_step, fig9_incore_vs_oocore,
                autotune_bench, kernel_micro):
        try:
            emit(mod.run())
        except Exception as e:  # keep the harness robust
            print(f"{mod.__name__},0,ERROR {e}", file=sys.stdout)
    try:
        rows = roofline.run()
        if rows:
            emit(rows)
        else:
            print("roofline,0,no dry-run artifacts (run scripts/run_dryrun_all.sh)")
    except Exception as e:
        print(f"roofline,0,ERROR {e}")


if __name__ == "__main__":
    main()
