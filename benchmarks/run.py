"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --dry-run

Prints ``name,us_per_call,derived`` CSV.  Rows labeled ``measured_cpu``
are wall-clock on this container; ``modeled`` rows evaluate the paper's
Sec. III analytic model over exact TransferStats geometry with RTX-3080
(paper-validation) or TPU-v5e (deployment-target) constants.  The
roofline rows read the multi-pod dry-run artifacts if present.

``--dry-run`` compiles the transfer/kernel op schedule for every engine x
paper stencil at the full out-of-core size and walks it with the dry-run
executor — plan construction and plan-derived accounting are exercised
end-to-end with zero device work (the CI smoke job).
"""
import argparse
import sys


def dry_run() -> None:
    from repro.core.executor import DryRunExecutor
    from repro.core.oocore import ENGINES
    from repro.core.stencil import PAPER_BENCHMARKS

    from .common import OOC_SZ, PAPER_CONFIG, paper_plan

    print("name,plan_ops,derived")
    ex = DryRunExecutor()
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in sorted(ENGINES):
            plan = paper_plan(engine, name, OOC_SZ, d, s_tb)
            _, s = ex.execute(plan)
            print(f"dryrun/{name}/{engine},{len(plan)},"
                  f"h2d_gb={s.h2d_bytes / 1e9:.2f} "
                  f"d2h_gb={s.d2h_bytes / 1e9:.2f} "
                  f"odc_gb={s.buffer_bytes / 1e9:.2f} "
                  f"kernels={s.kernel_calls} "
                  f"redundancy={s.redundancy:.4f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="compile + cost every engine's plan, no device work")
    args = ap.parse_args(argv)
    if args.dry_run:
        dry_run()
        return

    from . import (
        autotune_bench, fig5_config_sweep, fig6_so2dr_vs_resreu,
        fig7_breakdown, fig8_single_step, fig9_incore_vs_oocore,
        kernel_micro, roofline,
    )
    from .common import emit

    print("name,us_per_call,derived")
    for mod in (fig6_so2dr_vs_resreu, fig7_breakdown, fig5_config_sweep,
                fig8_single_step, fig9_incore_vs_oocore, autotune_bench,
                kernel_micro):
        try:
            emit(mod.run())
        except Exception as e:  # keep the harness robust
            print(f"{mod.__name__},0,ERROR {e}", file=sys.stdout)
    try:
        rows = roofline.run()
        if rows:
            emit(rows)
        else:
            print("roofline,0,no dry-run artifacts (run scripts/run_dryrun_all.sh)")
    except Exception as e:
        print(f"roofline,0,ERROR {e}")


if __name__ == "__main__":
    main()
