"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --dry-run
    PYTHONPATH=src python -m benchmarks.run --dry-run --codec all --json BENCH_plan.json
    PYTHONPATH=src python -m benchmarks.run --exec --executor double_buffered \
        --fused-step reference --json BENCH_exec.json

Prints ``name,us_per_call,derived`` CSV.  Rows labeled ``measured_cpu``
are wall-clock on this container; ``modeled`` rows evaluate the paper's
Sec. III analytic model over exact TransferStats geometry with RTX-3080
(paper-validation) or TPU-v5e (deployment-target) constants.  The
roofline rows read the multi-pod dry-run artifacts if present.

``--dry-run`` compiles the transfer/kernel op schedule for every engine x
paper stencil at the full out-of-core size and walks it with the dry-run
executor — plan construction and plan-derived accounting are exercised
end-to-end with zero device work (the CI smoke job).  Each record also
carries the deterministic lowering metrics (stage count, shape buckets =
max kernel compiles) from :func:`repro.core.lower.lower`.  ``--codec``
sweeps transfer codecs (``all`` = every registered codec) and reports raw
vs wire bytes; ``--json`` writes the records as machine-readable JSON for
the CI bench-gate (``benchmarks/check_regression.py`` diffs byte and
op-count/cache metrics against the committed ``benchmarks/baselines.json``).

``--exec`` *executes* every engine x paper stencil at a small real size
through the lowered executors (``--executor``, ``--fused-step`` pick the
interpreter and the kernel-dispatch implementation) and reports the
:class:`~repro.core.lower.ExecStats` wall-clock-per-op-class and
compilation-cache counters.  Timings are machine-dependent and never
gate CI; the JSON is uploaded as a non-gating artifact.

``--dry-run`` also sweeps plan *geometry*: ``--chunk-axis 1`` reorients
the 2-D engine sweep to column chunking (keys gain an ``/axisA``
suffix), and ``--tile T0,T1[,T2]`` / ``--time-depth T[,T...]`` override
the committed box_tb tile-grid x time-depth sweep on the 3-D
``heat3d1r`` workload.  Every dry-run record carries its box geometry
(``shape``, ``chunk_axis``, ``tiles``, ``time_depth``).

``--inject-fault`` is the chaos smoke (the CI ``chaos`` job): a small
SO2DR run with a seeded transient-fault schedule absorbed by the retry
loop, then a terminal kernel fault at every round recovered through the
HostCommit checkpoint/resume path — each variant must be bit-identical
to the uninterrupted run (exit code 1 on any mismatch).

Unknown ``--engine``/``--codec``/``--executor``/``--fused-step`` names,
geometry flags outside ``--dry-run``, and infeasible ``--tile`` x
``--time-depth`` combinations (apron deeper than a tile) are a hard
error (exit code 2), not a silent skip.
"""
import argparse
import json
import sys

# --exec workload: small enough to run on a CPU container in seconds,
# big enough that every engine produces multi-chunk, multi-round plans
EXEC_SZ = 192
EXEC_STEPS = 8
EXEC_D = 4
EXEC_S_TB = 4
EXEC_K_ON = 2


def _resolve_names(requested, known, kind, parser):
    """Expand 'all' and validate names against a registry; exit 2 on
    unknown names instead of silently skipping them."""
    if requested in (None, "all"):
        return sorted(known)
    names = [s for s in requested.split(",") if s]
    for name in names:
        if name not in known:
            parser.error(
                f"unknown {kind} {name!r}; known: {sorted(known)} (or 'all')")
    return names


def _write_json(records, json_path) -> None:
    with open(json_path, "w") as f:
        json.dump(records, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(records)} records to {json_path}", file=sys.stderr)


# sharded (L2) dry-run workload: the full 38400^2 framed domain over a
# 4x2 chip mesh, k_ici sweeping the per-step-exchange baseline (k=1)
# against communication-avoiding depths
SHARD_MESH = (4, 2)
SHARD_K_ICI = (1, 4, 8)

# hierarchical dry-run workload: 1024^3 heat3d1r (trailing third axis)
# over a 2x2 mesh whose shard working sets exceed a 1 GiB device budget,
# so each ShardKernel expands into a nested box_tb streaming program;
# the halo codec sweep shows ici_wire_bytes trading against raw payload
HIER_STENCIL = "heat3d1r"
HIER_SIDE = 1026                  # framed Y = X (interior 1024)
HIER_TRAILING = (1026,)
HIER_MESH = (2, 2)
HIER_K_ICI = 4
HIER_STEPS = 16
HIER_C_DEV = 1 << 30
HIER_CODECS = ("identity", "zrle", "bf16")

# 3-D box temporal-blocking dry-run workload: a 1024^3 interior (4.3 GB
# per array — out-of-core on the paper's 10 GB GPU), tile grids on the
# leading two axes x time depths.  Geometry only: the dry-run executor
# never allocates the domain.
BOX_STENCIL = "heat3d1r"
BOX_SHAPE = (1026, 1026, 1026)
BOX_STEPS = 16
BOX_TILES = ((2, 2), (4, 4))
BOX_DEPTHS = (2, 4)


def _plan_geometry(plan) -> dict:
    """Box geometry of a compiled plan, recorded with every dry-run row."""
    return {
        "shape": list(plan.shape),
        "chunk_axis": plan.chunk_axis,
        "tiles": list(plan.tiles) if plan.tiles else [plan.d],
        "time_depth": plan.k_off,
    }


def _box_records(ex, records, codecs, tile_grid=BOX_TILES,
                 depths=BOX_DEPTHS) -> None:
    from repro.core.compress import compress_plan
    from repro.core.lower import lower
    from repro.core.oocore import compile_box_plan
    from repro.core.stencil import get_stencil

    st = get_stencil(BOX_STENCIL)
    for tiles in tile_grid:
        for t in depths:
            base = compile_box_plan(st, BOX_SHAPE, BOX_STEPS, tiles, t)
            for codec in codecs:
                plan = compress_plan(base, codec)
                _, s = ex.execute(plan)
                lowering = lower(plan).describe()
                tag = "x".join(str(x) for x in tiles)
                key = f"{BOX_STENCIL}/box_tb/tiles{tag}/t{t}/{codec}"
                print(f"dryrun/{key},{len(plan)},"
                      f"wire_gb={s.wire_bytes / 1e9:.2f} "
                      f"odc_gb={s.buffer_bytes / 1e9:.2f} "
                      f"kernels={s.kernel_calls} "
                      f"redundancy={s.redundancy:.4f}")
                records[key] = {
                    "plan_ops": len(plan),
                    "raw_bytes": s.transfer_bytes,
                    "wire_bytes": s.wire_bytes,
                    "h2d_wire_bytes": s.h2d_wire_bytes,
                    "d2h_wire_bytes": s.d2h_wire_bytes,
                    "buffer_bytes": s.buffer_bytes,
                    "kernel_calls": s.kernel_calls,
                    "redundant_elements": s.redundant_elements,
                    "stage_count": lowering["stage_count"],
                    "shape_buckets": lowering["shape_buckets"],
                    "box": _plan_geometry(plan),
                }


def _sharded_records(ex, records) -> None:
    from repro.core.shard import compile_sharded
    from repro.core.stencil import PAPER_BENCHMARKS

    from .common import N_STEPS, OOC_SZ

    for name in PAPER_BENCHMARKS:
        for k_ici in SHARD_K_ICI:
            plan = compile_sharded(name, OOC_SZ, OOC_SZ, N_STEPS, k_ici,
                                   SHARD_MESH)
            _, s = ex.execute(plan)
            key = (f"sharded/{name}/mesh{SHARD_MESH[0]}x{SHARD_MESH[1]}"
                   f"/k{k_ici}")
            print(f"dryrun/{key},{len(plan)},"
                  f"ici_gb={s.ici_bytes / 1e9:.2f} "
                  f"per_round_mb={plan.collective_bytes_per_round / 1e6:.2f} "
                  f"halo_ops={s.halo_ops} "
                  f"kernels={s.kernel_calls} "
                  f"redundancy={s.redundancy:.6f}")
            records[key] = {
                "plan_ops": len(plan),
                "raw_bytes": s.transfer_bytes,
                "ici_bytes": s.ici_bytes,
                "collective_bytes_per_round": plan.collective_bytes_per_round,
                "halo_ops": s.halo_ops,
                "kernel_calls": s.kernel_calls,
                "redundant_elements": s.redundant_elements,
                "stage_count": len(plan.barriers),
            }


def _hierarchy_records(ex, records) -> None:
    from repro.core.hierarchy import compile_hierarchical

    for codec in HIER_CODECS:
        plan = compile_hierarchical(
            HIER_STENCIL, HIER_SIDE, HIER_SIDE, HIER_STEPS, HIER_K_ICI,
            HIER_MESH, c_dev=HIER_C_DEV, inner_engine="box_tb",
            codec=None if codec == "identity" else codec,
            trailing=HIER_TRAILING)
        _, s = ex.execute(plan)
        key = (f"hier/{HIER_STENCIL}/mesh{HIER_MESH[0]}x{HIER_MESH[1]}"
               f"/k{HIER_K_ICI}/{codec}")
        print(f"dryrun/{key},{len(plan)},"
              f"ici_gb={s.ici_bytes / 1e9:.2f} "
              f"ici_wire_gb={s.ici_wire_bytes / 1e9:.2f} "
              f"h2d_gb={s.h2d_bytes / 1e9:.2f} "
              f"inner_chunks={plan.inner_chunks} "
              f"kernels={s.kernel_calls} "
              f"redundancy={s.redundancy:.4f}")
        records[key] = {
            "plan_ops": len(plan),
            "raw_bytes": s.transfer_bytes,
            "wire_bytes": s.wire_bytes,
            "buffer_bytes": s.buffer_bytes,
            "ici_bytes": s.ici_bytes,
            "ici_wire_bytes": s.ici_wire_bytes,
            "collective_bytes_per_round": plan.collective_bytes_per_round,
            "collective_wire_bytes_per_round":
                plan.collective_wire_bytes_per_round,
            "halo_ops": s.halo_ops,
            "codec_ops": s.codec_ops,
            "kernel_calls": s.kernel_calls,
            "inner_chunks": plan.inner_chunks,
            "redundant_elements": s.redundant_elements,
            "stage_count": len(plan.barriers),
        }


def dry_run(engines, codecs, json_path=None, chunk_axis=0,
            tile_grid=BOX_TILES, depths=BOX_DEPTHS) -> None:
    from repro.core.compress import compress_plan
    from repro.core.executor import DryRunExecutor
    from repro.core.lower import lower
    from repro.core.stencil import PAPER_BENCHMARKS

    from .common import OOC_SZ, PAPER_CONFIG, paper_plan

    print("name,plan_ops,derived")
    ex = DryRunExecutor()
    records = {}
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in engines:
            base = paper_plan(engine, name, OOC_SZ, d, s_tb,
                              chunk_axis=chunk_axis)
            for codec in codecs:
                plan = compress_plan(base, codec)
                _, s = ex.execute(plan)
                # deterministic lowering metrics: stage programs + shape
                # buckets (= the kernel-compile ceiling), no execution
                lowering = lower(plan).describe()
                key = f"{name}/{engine}/{codec}"
                if chunk_axis:
                    key += f"/axis{chunk_axis}"
                print(f"dryrun/{key},{len(plan)},"
                      f"h2d_gb={s.h2d_bytes / 1e9:.2f} "
                      f"d2h_gb={s.d2h_bytes / 1e9:.2f} "
                      f"wire_gb={s.wire_bytes / 1e9:.2f} "
                      f"ratio={s.compression_ratio:.3f} "
                      f"odc_gb={s.buffer_bytes / 1e9:.2f} "
                      f"kernels={s.kernel_calls} "
                      f"buckets={lowering['shape_buckets']} "
                      f"redundancy={s.redundancy:.4f}")
                records[key] = {
                    "plan_ops": len(plan),
                    "raw_bytes": s.transfer_bytes,
                    "wire_bytes": s.wire_bytes,
                    "h2d_wire_bytes": s.h2d_wire_bytes,
                    "d2h_wire_bytes": s.d2h_wire_bytes,
                    "buffer_bytes": s.buffer_bytes,
                    "kernel_calls": s.kernel_calls,
                    "stage_count": lowering["stage_count"],
                    "shape_buckets": lowering["shape_buckets"],
                    "box": _plan_geometry(plan),
                }
    # 3-D box temporal-blocking plans (trapezoid aprons), the multi-chip
    # (L2) sharded plans (ICI + ghost-wedge accounting), then the
    # hierarchical plans (nested L1 streaming inside shards, halo-codec
    # wire bytes) — all gated by check_regression.py next to the row
    # byte records
    if chunk_axis == 0:
        _box_records(ex, records, codecs, tile_grid, depths)
        _sharded_records(ex, records)
        _hierarchy_records(ex, records)
    if json_path:
        _write_json(records, json_path)


def exec_bench(engines, codecs, executor_name, fused_impl,
               json_path=None, profile=None) -> None:
    import numpy as np

    from repro.core.autotune import predicted_makespan
    from repro.core.executor import get_executor
    from repro.core.oocore import compile_plan
    from repro.core.stencil import PAPER_BENCHMARKS, get_stencil
    from repro.kernels.dispatch import DispatchPolicy

    hw_prof = profile.as_hardware() if profile is not None else None
    print("name,wall_ms,derived")
    records = {}
    policy = DispatchPolicy(impl=fused_impl)
    for name in PAPER_BENCHMARKS:
        st = get_stencil(name)
        Y = X = EXEC_SZ + 2 * st.radius
        x = np.random.default_rng(42).standard_normal((Y, X)).astype(np.float32)
        for engine in engines:
            d_eff = 1 if engine == "incore" else EXEC_D
            k_on = 1 if engine == "resreu" else EXEC_K_ON
            for codec in codecs:
                plan = compile_plan(engine, st, Y, X, EXEC_STEPS, d_eff,
                                    EXEC_S_TB, k_on, codec=codec)
                ex = get_executor(executor_name, policy=policy)
                _, _ = ex.execute(plan, x)
                es = ex.exec_stats
                derived = ""
                if hw_prof is not None:
                    # calibrated prediction vs this run's wall clock —
                    # the per-record model-vs-measured attribution
                    es.modeled_s = predicted_makespan(plan, hw_prof)
                    es.model_error = ((es.modeled_s - es.wall_s)
                                      / max(es.wall_s, 1e-12))
                    derived = (f" modeled_ms={es.modeled_s * 1e3:.1f} "
                               f"model_err={es.model_error:+.2f}")
                key = f"{name}/{engine}/{codec}"
                print(f"exec/{key},{es.wall_s * 1e3:.1f},"
                      f"impl={es.kernel_impl} "
                      f"kernels={es.kernel_calls} "
                      f"compiles={es.kernel_compiles} "
                      f"hits={es.kernel_cache_hits} "
                      f"buckets={es.shape_buckets} "
                      f"stages={es.stage_count}" + derived)
                rec = es.as_dict()
                rec["executor"] = executor_name
                if profile is not None:
                    rec["profile_id"] = profile.profile_id
                records[key] = rec
    if json_path:
        _write_json(records, json_path)


def inject_fault_smoke(seed: int) -> int:
    """Chaos smoke: faulted runs must stay bit-identical to clean runs.

    Two drills on a small SO2DR workload (zero devices beyond the CPU
    backend): a seeded transient-transfer schedule fully absorbed by the
    bounded-backoff retry loop, and a terminal kernel fault at every
    round recovered through ``run_with_recovery`` + the HostCommit
    checkpointer.  Returns a process exit code (1 = a recovered run
    diverged from the uninterrupted one)."""
    import tempfile

    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.core.executor import EagerExecutor
    from repro.core.faults import (
        KERNEL_FAULT, FaultPlan, FaultTrigger, RetryPolicy,
    )
    from repro.core.oocore import compile_plan
    from repro.core.recovery import PlanCheckpointer, run_with_recovery
    from repro.core.stencil import get_stencil

    st = get_stencil("star2d1r")
    plan = compile_plan("so2dr", st, 64, 32, 8, 2, 4, 2)
    x = np.random.default_rng(seed).standard_normal((64, 32)) \
        .astype(np.float32)
    ref, _ = EagerExecutor().execute(plan, x)
    retry = RetryPolicy(sleep=lambda s: None)
    failures = 0

    print("name,ok,derived")
    faults = FaultPlan.seeded(seed, plan, n_faults=3)
    ex = EagerExecutor()
    host, _ = run_with_recovery(plan, x, executor=ex, faults=faults,
                                retry=retry)
    ok = np.array_equal(host, ref)
    failures += not ok
    print(f"chaos/transient_seeded,{int(ok)},"
          f"faults={ex.exec_stats.faults_injected} "
          f"retries={ex.exec_stats.retries}")

    for rnd in sorted({op.round for op in plan.ops}):
        faults = FaultPlan([FaultTrigger(round=rnd, chunk=None,
                                         op_class="*", kind=KERNEL_FAULT)])
        ex = EagerExecutor()
        with tempfile.TemporaryDirectory() as d:
            host, _ = run_with_recovery(
                plan, x, executor=ex, faults=faults,
                checkpoint=PlanCheckpointer(CheckpointManager(d), plan))
        ok = np.array_equal(host, ref)
        failures += not ok
        print(f"chaos/kernel_fault_round{rnd},{int(ok)},"
              f"resumes={ex.exec_stats.resumes} "
              f"faults={ex.exec_stats.faults_injected}")

    if failures:
        print(f"chaos: {failures} recovered run(s) diverged from the "
              f"uninterrupted reference", file=sys.stderr)
        return 1
    print("chaos: every faulted run bit-identical to the clean run",
          file=sys.stderr)
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="compile + cost every engine's plan, no device work")
    ap.add_argument("--exec", dest="exec_bench", action="store_true",
                    help="execute every engine at a small size; report "
                         "ExecStats wall clock + cache counters (non-gating)")
    ap.add_argument("--engine", default="all",
                    help="comma-separated engine names, or 'all' (default)")
    ap.add_argument("--codec", default="identity",
                    help="comma-separated transfer codecs, or 'all' "
                         "(default: identity — uncompressed wire bytes)")
    ap.add_argument("--executor", default="eager",
                    help="executor for --exec (eager | double_buffered)")
    ap.add_argument("--fused-step", default="auto",
                    help="kernel-dispatch impl for --exec "
                         "(auto | reference | pallas | pallas_db | mxu)")
    ap.add_argument("--inject-fault", action="store_true",
                    help="chaos smoke: seeded fault injection + "
                         "checkpoint/resume must stay bit-identical to "
                         "the clean run (exit 1 on divergence)")
    ap.add_argument("--fault-seed", type=int, default=0, metavar="S",
                    help="seed for the --inject-fault schedule (default 0)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write dry-run/exec records as JSON")
    ap.add_argument("--profile", metavar="PATH", default=None,
                    help="DeviceProfile JSON (benchmarks/calibrate.py): "
                         "price modeled rows with the calibrated constants "
                         "— --exec records gain modeled_s/model_error, the "
                         "measured suite adds a profile-priced autotune row")
    ap.add_argument("--chunk-axis", type=int, default=0, metavar="A",
                    help="streaming axis for the --dry-run engine sweep "
                         "(0 = the paper's row chunking; 1 = column "
                         "chunking of the same 2-D domains)")
    ap.add_argument("--tile", default=None, metavar="T0,T1[,T2]",
                    help="tile grid for the --dry-run box_tb sweep, e.g. "
                         "'2,2' (default: the committed "
                         f"{'/'.join('x'.join(map(str, t)) for t in BOX_TILES)} grids)")
    ap.add_argument("--time-depth", default=None, metavar="T[,T...]",
                    help="time depth(s) per H2D round trip for the "
                         "--dry-run box_tb sweep (default: "
                         f"{','.join(map(str, BOX_DEPTHS))})")
    args = ap.parse_args(argv)

    from repro.core.compress import CODECS
    from repro.core.executor import PLAN_EXECUTORS
    from repro.core.oocore import ENGINES, compile_box_plan
    from repro.core.stencil import get_stencil
    from repro.kernels.dispatch import KERNEL_IMPLS

    engines = _resolve_names(args.engine, ENGINES, "engine", ap)
    codecs = _resolve_names(args.codec, CODECS, "codec", ap)

    if sum((args.dry_run, args.exec_bench, args.inject_fault)) > 1:
        ap.error("--dry-run, --exec, and --inject-fault are mutually "
                 "exclusive")
    if args.fault_seed != 0 and not args.inject_fault:
        ap.error("--fault-seed only applies to --inject-fault")
    profile = None
    if args.profile is not None:
        if args.dry_run or args.inject_fault:
            ap.error("--profile applies where a Hardware is implied "
                     "(--exec and the measured suite); dry-run records "
                     "are plan geometry and the chaos smoke prices "
                     "nothing")
        from repro.core.calibrate import DeviceProfile, ProfileError
        try:
            profile = DeviceProfile.load(args.profile)
        except (OSError, ProfileError, ValueError) as e:
            ap.error(f"--profile {args.profile!r}: {e}")
    if args.inject_fault:
        if args.json or args.engine != "all" or args.codec != "identity":
            ap.error("--inject-fault takes only --fault-seed (the chaos "
                     "smoke runs one committed workload)")
        sys.exit(inject_fault_smoke(args.fault_seed))
    box_flags = args.tile is not None or args.time_depth is not None
    if (args.chunk_axis != 0 or box_flags) and not args.dry_run:
        ap.error("--chunk-axis/--tile/--time-depth only apply to --dry-run "
                 "(plan geometry knobs; the measured/exec paths run the "
                 "committed configurations)")
    if args.chunk_axis not in (0, 1):
        ap.error(f"--chunk-axis must be 0 or 1 for the 2-D paper domains, "
                 f"got {args.chunk_axis}")
    if args.chunk_axis != 0 and box_flags:
        ap.error("--tile/--time-depth sweep the box_tb engine on the 3-D "
                 "workload; --chunk-axis reorients the 2-D row sweep — "
                 "pick one")
    tile_grid, depths = BOX_TILES, BOX_DEPTHS
    if args.tile is not None:
        try:
            tiles = tuple(int(s) for s in args.tile.split(","))
        except ValueError:
            ap.error(f"--tile expects comma-separated integers, "
                     f"got {args.tile!r}")
        if not tiles or any(t < 1 for t in tiles) or len(tiles) > len(BOX_SHAPE):
            ap.error(f"--tile needs 1..{len(BOX_SHAPE)} counts >= 1, "
                     f"got {args.tile!r}")
        tile_grid = (tiles,)
    if args.time_depth is not None:
        try:
            depths = tuple(int(s) for s in args.time_depth.split(","))
        except ValueError:
            ap.error(f"--time-depth expects comma-separated integers, "
                     f"got {args.time_depth!r}")
        if not depths or any(t < 1 for t in depths):
            ap.error(f"--time-depth needs positive integers, "
                     f"got {args.time_depth!r}")
    if box_flags:
        # fail fast on infeasible geometry (apron deeper than a tile)
        # instead of half-writing a record set
        st = get_stencil(BOX_STENCIL)
        for tiles in tile_grid:
            for t in depths:
                try:
                    compile_box_plan(st, BOX_SHAPE, 1, tiles, t)
                except ValueError as e:
                    ap.error(f"--tile {','.join(map(str, tiles))} "
                             f"--time-depth {t}: {e}")
    if args.dry_run:
        dry_run(engines, codecs, json_path=args.json,
                chunk_axis=args.chunk_axis, tile_grid=tile_grid,
                depths=depths)
        return
    if args.exec_bench:
        # the sharded executors interpret ShardedPlans, not the
        # single-device engine schedules --exec sweeps
        if args.executor not in PLAN_EXECUTORS:
            ap.error(f"unknown --executor {args.executor!r}; known: "
                     f"{sorted(PLAN_EXECUTORS)}")
        if args.fused_step != "auto" and args.fused_step not in KERNEL_IMPLS:
            ap.error(f"unknown --fused-step {args.fused_step!r}; known: "
                     f"{sorted(KERNEL_IMPLS)} (or 'auto')")
        exec_bench(engines, codecs, args.executor, args.fused_step,
                   json_path=args.json, profile=profile)
        return
    if args.json or args.engine != "all" or args.codec != "identity":
        ap.error("--engine/--codec/--json only apply to --dry-run/--exec; "
                 "the measured path always runs the full figure suite")
    if profile is not None:
        # autotune_bench reads TUNE_PROFILE: the measured suite gains a
        # row priced with this machine's calibrated constants
        import os
        os.environ["TUNE_PROFILE"] = args.profile

    from . import (
        autotune_bench, fig5_config_sweep, fig6_so2dr_vs_resreu,
        fig7_breakdown, fig7_codec_breakdown, fig8_single_step,
        fig9_incore_vs_oocore, kernel_micro, roofline,
    )
    from .common import emit

    print("name,us_per_call,derived")
    for mod in (fig6_so2dr_vs_resreu, fig7_breakdown, fig7_codec_breakdown,
                fig5_config_sweep, fig8_single_step, fig9_incore_vs_oocore,
                autotune_bench, kernel_micro):
        try:
            emit(mod.run())
        except Exception as e:  # keep the harness robust
            print(f"{mod.__name__},0,ERROR {e}", file=sys.stdout)
    try:
        rows = roofline.run()
        if rows:
            emit(rows)
        else:
            print("roofline,0,no dry-run artifacts "
                  "(run: PYTHONPATH=src python -m repro.launch.dryrun --all)")
    except Exception as e:
        print(f"roofline,0,ERROR {e}")


if __name__ == "__main__":
    main()
