"""Shared benchmark plumbing.

Paper workloads (Table III): out-of-core 38400^2 fp32 (11.0 GB counting
the in/out array pair), in-core 12800^2 (1.2 GB), 640 total steps,
3 streams.  Numbers produced here are either

* ``measured_cpu``  — wall-clock on this container (jnp/interpret Pallas), or
* ``modeled_tpu``   — the paper's Sec. III model + exact TransferStats
  geometry, evaluated with TPU-v5e constants (and RTX3080 constants where
  we sanity-check against the paper's own numbers),

and every CSV row labels which.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.core.analytic import EngineTimes, Hardware, TPU_V5E, times_from_plan
from repro.core.oocore import compile_plan
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil  # noqa: F401 (PAPER_BENCHMARKS re-exported to fig modules)

OOC_SZ = 38400       # out-of-core domain (11.0 GB with 2 arrays)
INC_SZ = 12800       # in-core domain (1.2 GB)
N_STEPS = 640
K_ON = 4             # the paper's four-step kernels

# the run-time configs the paper selects per benchmark (Sec. V-B)
PAPER_CONFIG = {
    "box2d1r": (4, 160),
    "box2d2r": (4, 160),
    "box2d3r": (4, 80),
    "box2d4r": (4, 40),
    "gradient2d": (4, 160),
}

PAPER_SPEEDUP_VS_RESREU = {
    "box2d1r": 4.22, "box2d2r": 2.94, "box2d3r": 1.97,
    "box2d4r": 1.19, "gradient2d": 3.59,
}


def paper_plan(engine: str, name: str, sz: int, d: int, s_tb: int,
               k_on: int = K_ON, n: int = N_STEPS, codec=None,
               chunk_axis: int = 0):
    """Compile one engine's op schedule for a paper workload.

    The single place encoding the benchmark conventions: the domain is
    framed (``sz + 2r`` per side), ResReu is pinned to single-step
    kernels (its defining constraint), and InCore streams the whole
    domain as one chunk.  ``codec`` wraps every transfer in
    Compress/Decompress ops (None = uncompressed); ``chunk_axis`` picks
    the streaming axis (0 = the paper's row chunking)."""
    st = get_stencil(name)
    Y = X = sz + 2 * st.radius
    k_on_eff = 1 if engine == "resreu" else k_on
    d_eff = 1 if engine == "incore" else d
    return compile_plan(engine, st, Y, X, n, d_eff, s_tb, k_on_eff,
                        codec=codec, chunk_axis=chunk_axis)


def modeled(engine: str, name: str, sz: int, d: int, s_tb: int,
            hw: Hardware = TPU_V5E, k_on: int = K_ON,
            n: int = N_STEPS) -> EngineTimes:
    return times_from_plan(paper_plan(engine, name, sz, d, s_tb, k_on, n), hw)


def timeit(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
