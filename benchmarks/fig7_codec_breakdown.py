"""Fig. 7-style raw-vs-wire transfer breakdown per codec.

For each paper stencil and out-of-core engine, compile the schedule
once, rewrite it per transfer codec (identity / bf16 / zrle), and read
the raw and wire H2D/D2H byte totals plus the modeled TPU-v5e phase
times off the plan.  Shows where on-the-fly compression
(arXiv 2204.11315) actually buys wall-clock: only transfer-bound
configs move, because the model charges the interconnect at wire bytes
while kernels are untouched.
"""
from repro.core.analytic import TPU_V5E, times_from_plan
from repro.core.compress import CODECS, compress_plan

from .common import N_STEPS, OOC_SZ, PAPER_BENCHMARKS, PAPER_CONFIG, emit, paper_plan


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in ("so2dr", "resreu", "naive_tb"):
            base = paper_plan(engine, name, OOC_SZ, d, s_tb)
            for codec in sorted(CODECS):
                plan = compress_plan(base, codec)
                s = plan.stats()
                t = times_from_plan(plan, TPU_V5E)
                rows.append((
                    f"fig7_codec/{name}/{engine}/{codec}",
                    t.total_overlapped() * 1e6 / N_STEPS,
                    f"modeled_tpu raw_gb={s.transfer_bytes / 1e9:.2f} "
                    f"wire_gb={s.wire_bytes / 1e9:.2f} "
                    f"ratio={s.compression_ratio:.3f} "
                    f"h2d={t.h2d:.3f} d2h={t.d2h:.3f} "
                    f"kernel={t.kernel:.3f} codec_ops={s.codec_ops}",
                ))
    return rows


if __name__ == "__main__":
    emit(run())
