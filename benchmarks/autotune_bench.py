"""Autotuner (paper Sec. VII future work): best modeled config per
benchmark per machine, with the automated Fig. 3a bottleneck decision.

Rows come from the unified :func:`repro.tune` entry point — one
``TuneResult`` spelling per candidate regardless of plan family.  Set
``TUNE_PROFILE`` to a :class:`~repro.core.calibrate.DeviceProfile` JSON
path to price the sweep with calibrated constants instead of the
hand-entered tables (rows then carry the profile id).
"""
import os

from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.calibrate import DeviceProfile
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil
from repro.core.tune import TuneSpec, tune

from .common import N_STEPS, OOC_SZ, emit


def run():
    rows = []
    profile = None
    if os.environ.get("TUNE_PROFILE"):
        profile = DeviceProfile.load(os.environ["TUNE_PROFILE"])
    machines = ((RTX3080_PAPER, "rtx3080"), (TPU_V5E, "tpu_v5e"))
    if profile is not None:
        machines = machines + ((profile.as_hardware(), profile.profile_id),)
    for name in PAPER_BENCHMARKS:
        st = get_stencil(name)
        sz = OOC_SZ
        spec = TuneSpec(stencil=name, shape=sz + 2 * st.radius,
                        steps=N_STEPS)
        for hw, tag in machines:
            is_prof = profile is not None and tag == profile.profile_id
            ranked = tune(spec, profile=profile if is_prof else None,
                          hw=None if is_prof else hw)
            if not ranked:
                continue
            b = ranked[0]
            c = b.config
            rows.append((
                f"autotune/{name}/{tag}",
                b.modeled_s * 1e6 / N_STEPS,
                f"modeled best engine={b.engine} d={c['d']} "
                f"s_tb={c['s_tb']} k_on={c['k_on']} "
                f"impl={c['kernel_impl']} next_target={b.bottleneck}"
                + (f" profile={b.profile_id}" if b.profile_id else ""),
            ))
    return rows


if __name__ == "__main__":
    emit(run())
