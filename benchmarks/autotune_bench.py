"""Autotuner (paper Sec. VII future work): best modeled config per
benchmark per machine, with the automated Fig. 3a bottleneck decision."""
from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.autotune import autotune
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil

from .common import N_STEPS, OOC_SZ, emit


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        st = get_stencil(name)
        for hw, tag in ((RTX3080_PAPER, "rtx3080"), (TPU_V5E, "tpu_v5e")):
            ranked = autotune(st, OOC_SZ, N_STEPS, hw)
            if not ranked:
                continue
            b = ranked[0]
            rows.append((
                f"autotune/{name}/{tag}",
                b.time_s * 1e6 / N_STEPS,
                f"modeled best engine={b.engine} d={b.d} s_tb={b.s_tb} "
                f"k_on={b.k_on} impl={b.kernel_impl} "
                f"next_target={b.bottleneck}",
            ))
    return rows


if __name__ == "__main__":
    emit(run())
