"""Paper Fig. 6: SO2DR vs ResReu speedups on the five stencil benchmarks
(out-of-core dataset), modeled on both the paper's RTX 3080 (validating
the reproduction against the paper's reported speedups) and TPU v5e (the
deployment target).
"""
from repro.core.analytic import RTX3080_PAPER, TPU_V5E

from .common import (
    N_STEPS, OOC_SZ, PAPER_BENCHMARKS, PAPER_CONFIG,
    PAPER_SPEEDUP_VS_RESREU, emit, modeled,
)


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for hw, tag in ((RTX3080_PAPER, "rtx3080"), (TPU_V5E, "tpu_v5e")):
            t_so = modeled("so2dr", name, OOC_SZ, d, s_tb, hw=hw)
            t_rr = modeled("resreu", name, OOC_SZ, d, s_tb, hw=hw)
            sp = t_rr.total_overlapped() / t_so.total_overlapped()
            rows.append((
                f"fig6/{name}/{tag}",
                t_so.total_overlapped() * 1e6 / N_STEPS,
                f"modeled speedup_vs_resreu={sp:.2f} "
                f"paper_reported={PAPER_SPEEDUP_VS_RESREU[name]}",
            ))
    return rows


if __name__ == "__main__":
    emit(run())
