"""Measured microbenchmarks on this container (honest CPU wall-clock):

* Pallas fused kernel (interpret mode) vs jnp reference — correctness-path
  cost, NOT TPU performance;
* SO2DR vs ResReu end-to-end on a small real domain with jnp kernels —
  shows the kernel-launch/interruption reduction (the paper's mechanism)
  even on CPU.
"""
import jax
import numpy as np

from repro.core.oocore import ResReu, SO2DR
from repro.core.stencil import get_stencil

from .common import emit, timeit


def run():
    rows = []
    rng = np.random.default_rng(0)

    # measured engine comparison on a real (small) domain
    st = get_stencil("box2d1r")
    Y = X = 1026
    x = rng.standard_normal((Y, X)).astype(np.float32)
    n, d, k_off, k_on = 32, 4, 16, 4
    so = SO2DR(d=d, k_off=k_off, k_on=k_on)
    rr = ResReu(d=d, k_off=k_off, k_on=k_on)
    t_so = timeit(lambda: so.run(x, st, n), iters=2)
    t_rr = timeit(lambda: rr.run(x, st, n), iters=2)
    _, s_so = so.run(x, st, n)
    _, s_rr = rr.run(x, st, n)
    rows.append((
        "micro/so2dr_vs_resreu/measured_cpu",
        t_so * 1e6,
        f"measured_cpu speedup={t_rr / t_so:.2f} "
        f"kernel_calls {s_so.kernel_calls} vs {s_rr.kernel_calls}",
    ))

    # Pallas interpret-mode kernel cost (validation path)
    from repro.kernels.ops import fused_stencil
    import jax.numpy as jnp
    xb = jnp.asarray(x[:258, :514])
    t_pal = timeit(lambda: jax.block_until_ready(
        fused_stencil(xb, "box2d1r", 4, True, True, tile=(64, 256))), iters=2)
    rows.append((
        "micro/pallas_fused_interpret/measured_cpu",
        t_pal * 1e6,
        "measured_cpu interpret=True (correctness path, not TPU perf)",
    ))
    return rows


if __name__ == "__main__":
    emit(run())
