"""Measured microbenchmarks on this container (honest CPU wall-clock):

* Pallas fused kernel (interpret mode) vs jnp reference — correctness-path
  cost, NOT TPU performance;
* SO2DR vs ResReu end-to-end on a small real domain with jnp kernels —
  shows the kernel-launch/interruption reduction (the paper's mechanism)
  even on CPU.
"""
import jax
import numpy as np

from repro.core.oocore import ResReu, SO2DR
from repro.core.stencil import get_stencil

from .common import emit, timeit


def run():
    rows = []
    rng = np.random.default_rng(0)

    # measured engine comparison on a real (small) domain
    st = get_stencil("box2d1r")
    Y = X = 1026
    x = rng.standard_normal((Y, X)).astype(np.float32)
    n, d, k_off, k_on = 32, 4, 16, 4
    so = SO2DR(d=d, k_off=k_off, k_on=k_on)
    rr = ResReu(d=d, k_off=k_off, k_on=k_on)
    t_so = timeit(lambda: so.run(x, st, n), iters=2)
    t_rr = timeit(lambda: rr.run(x, st, n), iters=2)
    _, s_so = so.run(x, st, n)
    _, s_rr = rr.run(x, st, n)
    rows.append((
        "micro/so2dr_vs_resreu/measured_cpu",
        t_so * 1e6,
        f"measured_cpu speedup={t_rr / t_so:.2f} "
        f"kernel_calls {s_so.kernel_calls} vs {s_rr.kernel_calls}",
    ))

    # Pallas interpret-mode kernel cost (validation path)
    from repro.kernels.ops import fused_stencil
    import jax.numpy as jnp
    xb = jnp.asarray(x[:258, :514])
    t_pal = timeit(lambda: jax.block_until_ready(
        fused_stencil(xb, "box2d1r", 4, True, True, tile=(64, 256))), iters=2)
    rows.append((
        "micro/pallas_fused_interpret/measured_cpu",
        t_pal * 1e6,
        "measured_cpu interpret=True (correctness path, not TPU perf)",
    ))

    rows.extend(calibration_rows())
    return rows


def calibration_rows():
    """Calibration-seed samples: the raw (feature, wall-clock) points the
    fitter in :mod:`repro.core.calibrate` least-squares-fits into a
    :class:`~repro.core.calibrate.DeviceProfile` — emitted here so the
    CSV keeps an eyeball-able record of what the fit consumed."""
    from repro.core.calibrate import (
        measure_codec, measure_interconnect, measure_kernel_impl,
    )

    rows = []
    for nbytes, t_h2d, t_d2h in measure_interconnect(
            sizes=(1 << 20, 4 << 20), iters=2):
        mb = nbytes / (1 << 20)
        rows.append((f"calib/transfer/{mb:g}MB/measured_cpu", t_h2d * 1e6,
                     f"measured_cpu h2d bw={nbytes / t_h2d / 1e9:.2f}GB/s "
                     f"d2h bw={nbytes / t_d2h / 1e9:.2f}GB/s"))
    for mem, flops, t in measure_kernel_impl(
            "reference", "box2d1r", bands=((130, 258), (258, 258)),
            steps_grid=(1, 2), iters=2):
        rows.append((f"calib/kernel/reference/{mem}B/measured_cpu", t * 1e6,
                     f"measured_cpu flops={flops} "
                     f"rate={flops / t / 1e9:.2f}GFLOP/s"))
    for codec in ("bf16", "zrle"):
        for nbytes, t_enc, t_dec in measure_codec(
                codec, sizes=(1 << 20,), iters=2):
            rows.append((
                f"calib/codec/{codec}/measured_cpu", t_enc * 1e6,
                f"measured_cpu enc={nbytes / t_enc / 1e9:.2f}GB/s "
                f"dec={nbytes / t_dec / 1e9:.2f}GB/s"))
    return rows


if __name__ == "__main__":
    emit(run())
