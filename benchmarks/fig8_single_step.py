"""Paper Fig. 8: per-kernel time of *single-step* kernels across radii.

The paper observes these are nearly constant in stencil radius on its GPU
(memory-bound at 39 flops/byte of headroom).  On TPU v5e the VPU's
4.8 flops/byte crossover means only r=1 stays memory-bound — the modeled
column quantifies that hardware-adaptation shift (DESIGN.md §2); the
measured column is this container's CPU wall time for the same kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analytic import RTX3080_PAPER, TPU_V5E
from repro.core.reference import step_domain
from repro.core.stencil import PAPER_BENCHMARKS, get_stencil

from .common import emit, timeit


def run():
    rows = []
    rng = np.random.default_rng(0)
    SZ = 1536
    for name in PAPER_BENCHMARKS:
        st = get_stencil(name)
        x = jnp.asarray(rng.standard_normal((SZ, SZ)).astype(np.float32))
        step = jax.jit(lambda a, n=st.name: step_domain(a, get_stencil(n)))
        t_cpu = timeit(lambda: jax.block_until_ready(step(x)))
        elems = (SZ - 2 * st.radius) ** 2
        for hw, tag in ((RTX3080_PAPER, "rtx3080"), (TPU_V5E, "tpu_v5e")):
            t_mem = 2 * 4 * elems / hw.bw_dmem
            t_cmp = st.flops_per_elem * elems / hw.peak_vpu_flops
            bound = "memory" if t_mem > t_cmp else "compute"
            rows.append((
                f"fig8/{name}/{tag}",
                max(t_mem, t_cmp) * 1e6,
                f"modeled single-step kernel; bound={bound} "
                f"mem_us={t_mem*1e6:.1f} comp_us={t_cmp*1e6:.1f}",
            ))
        rows.append((
            f"fig8/{name}/measured_cpu",
            t_cpu * 1e6,
            f"measured_cpu single-step jnp @ {SZ}x{SZ}",
        ))
    return rows


if __name__ == "__main__":
    emit(run())
