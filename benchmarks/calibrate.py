"""Fit a measured-cost DeviceProfile for this machine's backend.

Runs the transfer / device-memory / kernel / codec microbenchmarks in
:mod:`repro.core.calibrate` on whatever backend JAX resolves here,
least-squares-fits the Sec. III model terms, and persists the versioned
profile JSON (loadable as a ``Hardware`` drop-in by ``tune``,
``StencilService``, and ``benchmarks/run.py --profile``).

    PYTHONPATH=src python -m benchmarks.calibrate --quick --out BENCH_profile.json

``--quick`` uses the small size ladders (seconds, CI-friendly); the
default full ladders take minutes but tighten the fit.  Exit status is
0 on a fitted profile, 1 when fitting fails.  Gate the result with
``benchmarks/check_regression.py --profile``.
"""
import argparse
import sys

from repro.core.calibrate import calibrate

from .common import emit


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fit a measured-cost DeviceProfile for this backend")
    ap.add_argument("--quick", action="store_true",
                    help="small size ladders (seconds; CI-friendly)")
    ap.add_argument("--out", default="BENCH_profile.json",
                    help="profile JSON path (default: %(default)s)")
    ap.add_argument("--stencil", default="box2d1r")
    ap.add_argument("--impls", default=None,
                    help="comma-separated kernel impls (default: ladder's)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    impls = tuple(args.impls.split(",")) if args.impls else None
    try:
        prof = calibrate(quick=args.quick, stencil=args.stencil,
                         kernel_impls=impls, seed=args.seed,
                         progress=lambda msg: print(f"# {msg}",
                                                    file=sys.stderr))
    except Exception as e:
        print(f"calibrate: fit failed: {e}", file=sys.stderr)
        return 1
    prof.save(args.out)

    hw = prof.as_hardware()
    rows = [
        (f"calibrate/{prof.profile_id}/bw_intc", 0.0,
         f"measured_cpu bw_intc={hw.bw_intc / 1e9:.3f}GB/s "
         f"t_ici_latency={hw.t_ici_latency * 1e6:.1f}us"),
        (f"calibrate/{prof.profile_id}/bw_dmem", 0.0,
         f"measured_cpu bw_dmem={hw.bw_dmem / 1e9:.3f}GB/s"),
        (f"calibrate/{prof.profile_id}/peak_vpu", 0.0,
         f"measured_cpu peak_vpu={hw.peak_vpu_flops / 1e9:.3f}GFLOP/s"),
    ]
    for impl, terms in sorted(prof.kernel_terms.items()):
        rows.append((
            f"calibrate/{prof.profile_id}/kernel/{impl}", 0.0,
            "measured_cpu " + " ".join(
                f"{k}={v:.4g}" for k, v in sorted(terms.items()))))
    for codec, thr in sorted(prof.codec_throughput.items()):
        rows.append((
            f"calibrate/{prof.profile_id}/codec/{codec}", 0.0,
            f"measured_cpu enc={thr['encode_bps'] / 1e9:.3f}GB/s "
            f"dec={thr['decode_bps'] / 1e9:.3f}GB/s"))
    for name, resid in sorted(prof.residuals.items()):
        rows.append((f"calibrate/{prof.profile_id}/residual/{name}",
                     0.0, f"measured_cpu rel_rms={resid:.4f}"))
    emit(rows)
    print(f"# profile written to {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
