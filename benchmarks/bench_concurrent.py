"""Concurrent-serving benchmark: sustained jobs/sec and latency tails.

    PYTHONPATH=src python -m benchmarks.bench_concurrent --json BENCH_serve.json

Drives a :class:`repro.serve.StencilService` through a mixed 8-job trace
(three stencils, four shapes, two codecs, a couple of deadlines) three
ways:

* **cold flush** — all 8 jobs interleaved by the cross-job scheduler on
  an empty cache: per-job latency (flush start -> job's last commit),
  sustained jobs/sec, p50/p99;
* **warm flush** — the same trace resubmitted (plus shapes the service
  has never seen that fall inside existing buckets): total
  ``kernel_compiles`` must be exactly 0 — this is the structural record
  CI gates on;
* **solo baseline** — each job run alone (warm, same double-buffered
  discipline) for the back-to-back comparison, measured and modeled;
* **faulted flush** — a 3-job batch with one terminally fault-injected
  job: graceful degradation is gated structurally (exactly one
  ``jobs_failed``, survivors complete, slot pool drains to zero).

Structural fields (``plan_ops``, ``stage_count``, ``shape_buckets``,
``kernel_compiles``) are deterministic functions of the planner, the
lowering, and the shared caches — ``check_regression.py`` gates them
exactly against ``benchmarks/baselines_serve.json``.  Wall-clock fields
(latency, jobs/sec, modeled seconds) are informational artifacts only.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.kernels.dispatch import DispatchPolicy
from repro.serve import StencilJob, StencilService

# (stencil, framed shape, codec, deadline) x fixed engine knobs.  Shapes
# repeat Y-heights within a (stencil, X) group on purpose: the warm pass
# must route every one of them to an already-compiled bucket.
TRACE = [
    ("box2d1r", (130, 130), "identity", None),
    ("gradient2d", (130, 130), "identity", 0.5),
    ("box2d1r", (106, 130), "zrle", None),
    ("box2d2r", (132, 132), "identity", None),
    ("box2d1r", (130, 130), "identity", 0.2),
    ("gradient2d", (114, 130), "identity", None),
    ("box2d2r", (108, 132), "zrle", None),
    ("box2d1r", (122, 130), "identity", None),
]
# unseen-at-warm-time heights that fall inside the buckets above
WARM_EXTRA = [
    ("box2d1r", (114, 130), "identity", None),
    ("box2d2r", (116, 132), "identity", None),
]
STEPS, D, S_TB, K_ON = 16, 4, 4, 2


def _jobs(trace):
    return [StencilJob(shape=shape, stencil=name, steps=STEPS, codec=codec,
                       deadline=deadline, d=D, s_tb=S_TB, k_on=K_ON)
            for name, shape, codec, deadline in trace]


def _flush(svc, jobs, rng):
    for job in jobs:
        svc.submit(job, rng.standard_normal(job.shape).astype(np.float32))
    t0 = time.perf_counter()
    results = svc.flush()
    wall = time.perf_counter() - t0
    return results, wall


def run(json_path=None):
    # pin the dispatch policy: CI runs on CPU and the structural records
    # must not depend on which backend "auto" resolves to
    svc = StencilService(policy=DispatchPolicy(impl="reference"))
    rng = np.random.default_rng(31)
    records = {}

    # -- cold: the 8-job mixed trace, interleaved --
    results, wall = _flush(svc, _jobs(TRACE), rng)
    lat = sorted(r.latency_s for r in results)
    cold_compiles = sum(r.exec_stats.kernel_compiles for r in results)
    for r in results:
        job = next(j for j in svc.last_admission if j.job_id == r.job_id)
        plan = job.compiled.plan
        records[f"serve/job{r.job_id}"] = {
            "stencil": plan.stencil, "shape": list(job.x.shape),
            "plan_ops": len(plan.ops),
            "stage_count": r.exec_stats.stage_count,
            "shape_buckets": r.exec_stats.shape_buckets,
            "latency_s": r.latency_s,            # non-gating
            "predicted_s": r.predicted_s,        # non-gating
        }
    mi = svc.modeled_makespan(interleaved=True)
    mb = svc.modeled_makespan(interleaved=False)
    records["serve/trace"] = {
        "jobs": len(results),
        "kernel_compiles": cold_compiles,
        "shape_buckets": len(svc.buckets),
        "jobs_per_s": len(results) / wall,                       # non-gating
        "p50_latency_s": float(np.percentile(lat, 50)),          # non-gating
        "p99_latency_s": float(np.percentile(lat, 99)),          # non-gating
        "modeled_interleaved_s": mi,                             # non-gating
        "modeled_back_to_back_s": mb,                            # non-gating
    }

    # -- warm: same trace + unseen in-bucket heights -> 0 compiles --
    results_w, wall_w = _flush(svc, _jobs(TRACE + WARM_EXTRA), rng)
    lat_w = sorted(r.latency_s for r in results_w)
    records["serve/warm"] = {
        "jobs": len(results_w),
        "kernel_compiles": sum(r.exec_stats.kernel_compiles
                               for r in results_w),
        "shape_buckets": len(svc.buckets),
        "jobs_per_s": len(results_w) / wall_w,                   # non-gating
        "p50_latency_s": float(np.percentile(lat_w, 50)),        # non-gating
        "p99_latency_s": float(np.percentile(lat_w, 99)),        # non-gating
    }

    # -- solo baseline: warm back-to-back, same pipelined discipline --
    t0 = time.perf_counter()
    solo = [svc.run_solo(job, rng.standard_normal(job.shape)
                         .astype(np.float32)) for job in _jobs(TRACE)]
    solo_wall = time.perf_counter() - t0
    records["serve/solo"] = {
        "jobs": len(solo),
        "kernel_compiles": sum(r.exec_stats.kernel_compiles for r in solo),
        "jobs_per_s": len(solo) / solo_wall,                     # non-gating
    }

    # -- faulted: graceful degradation under a terminal injected fault --
    # one job of a 3-job warm batch dies mid-flush; the record gates that
    # exactly one job fails, the survivors complete, and the slot pool
    # fully drains (a lease leak here is a serving-capacity regression)
    from repro.core.faults import KERNEL_FAULT, FaultPlan, FaultTrigger

    faulted_trace = _jobs(TRACE[:3])
    faults = FaultPlan([FaultTrigger(round=1, chunk=0, op_class="*",
                                     kind=KERNEL_FAULT)])
    for i, job in enumerate(faulted_trace):
        x = rng.standard_normal(job.shape).astype(np.float32)
        if i == 1:
            job = dataclasses.replace(job, faults=faults)
        svc.submit(job, x)
    results_f = svc.flush()
    records["serve/faulted"] = {
        "jobs": len(results_f),
        "jobs_failed": sum(r.status == "failed" for r in results_f),
        "jobs_ok": sum(r.status == "ok" for r in results_f),
        "faults_injected": sum(r.exec_stats.faults_injected
                               for r in results_f),
        "slot_pool_in_use_after": svc.slot_pool.in_use,
        "kernel_compiles": sum(r.exec_stats.kernel_compiles
                               for r in results_f),
    }

    print(f"cold : {records['serve/trace']['jobs_per_s']:6.2f} jobs/s  "
          f"p50={records['serve/trace']['p50_latency_s']*1e3:7.1f}ms  "
          f"p99={records['serve/trace']['p99_latency_s']*1e3:7.1f}ms  "
          f"compiles={cold_compiles}")
    print(f"warm : {records['serve/warm']['jobs_per_s']:6.2f} jobs/s  "
          f"p50={records['serve/warm']['p50_latency_s']*1e3:7.1f}ms  "
          f"p99={records['serve/warm']['p99_latency_s']*1e3:7.1f}ms  "
          f"compiles={records['serve/warm']['kernel_compiles']}")
    print(f"solo : {records['serve/solo']['jobs_per_s']:6.2f} jobs/s "
          f"(warm back-to-back baseline)")
    print(f"fault: {records['serve/faulted']['jobs_failed']}/"
          f"{records['serve/faulted']['jobs']} jobs failed by injection, "
          f"{records['serve/faulted']['jobs_ok']} survived, "
          f"pool_in_use={records['serve/faulted']['slot_pool_in_use_after']}")
    print(f"model: interleaved {mi*1e6:.1f}us vs back-to-back {mb*1e6:.1f}us "
          f"({(1 - mi/mb)*100:.0f}% win)")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
        print(f"wrote {json_path} ({len(records)} records)")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the record dict as JSON (CI gates the "
                         "structural fields via check_regression.py)")
    args = ap.parse_args(argv)
    run(json_path=args.json)


if __name__ == "__main__":
    main()
