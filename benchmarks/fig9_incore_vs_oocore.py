"""Paper Fig. 9/10: in-core code vs the two out-of-core codes on the
in-core dataset (12800^2, fits in device memory).

The paper's surprise result — SO2DR ~matching or beating the in-core code
(1.14x mean) — rests on multi-stream kernel overlap; our Sec. III model
treats kernels as serialized, so SO2DR == in-core is the modeled
expectation (ratio 1.0) and ResReu shows the single-step-kernel penalty.
"""
from .common import INC_SZ, N_STEPS, PAPER_BENCHMARKS, emit, modeled


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        t_inc = modeled("incore", name, INC_SZ, 1, N_STEPS)
        # in-core: transfer excluded per the paper's protocol
        base = t_inc.kernel
        for engine in ("so2dr", "resreu"):
            t = modeled(engine, name, INC_SZ, 4, 160)
            ratio = t.total_overlapped() / base
            rows.append((
                f"fig9/{name}/{engine}",
                t.total_overlapped() * 1e6 / N_STEPS,
                f"modeled_tpu vs_incore={ratio:.2f} "
                "(paper reports so2dr ~0.88-1.0x of incore)",
            ))
    return rows


if __name__ == "__main__":
    emit(run())
