"""Paper Fig. 7: execution-time breakdown (HtoD / kernel / O-D / DtoH)
for SO2DR vs ResReu on the out-of-core dataset, TPU-v5e model.

Since the plan/execute refactor the four bars are read directly off the
compiled op schedule: each Fig. 7 category is one op type of the plan IR
(H2D -> HtoD, FusedKernel -> kernel, BufferRead/Write -> O-D copies,
D2H -> DtoH), so the breakdown and the executors consume the same object.
"""
from .common import N_STEPS, OOC_SZ, PAPER_BENCHMARKS, PAPER_CONFIG, emit, paper_plan
from repro.core.analytic import TPU_V5E, times_from_plan


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in ("so2dr", "resreu", "naive_tb"):
            plan = paper_plan(engine, name, OOC_SZ, d, s_tb)
            t = times_from_plan(plan, TPU_V5E)
            ops = plan.op_counts()
            rows.append((
                f"fig7/{name}/{engine}",
                t.total_serial * 1e6 / N_STEPS,
                f"modeled_tpu h2d={t.h2d:.3f} kernel={t.kernel:.3f} "
                f"odc={t.odc:.4f} d2h={t.d2h:.3f} "
                f"kmem={t.kernel_mem:.3f} kcomp={t.kernel_compute:.3f} "
                f"plan_ops={len(plan)} kernels={ops.get('FusedKernel', 0)}",
            ))
    return rows


if __name__ == "__main__":
    emit(run())
