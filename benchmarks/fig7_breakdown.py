"""Paper Fig. 7: execution-time breakdown (HtoD / kernel / O-D / DtoH)
for SO2DR vs ResReu on the out-of-core dataset, TPU-v5e model.
"""
from .common import N_STEPS, OOC_SZ, PAPER_BENCHMARKS, PAPER_CONFIG, emit, modeled


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        d, s_tb = PAPER_CONFIG[name]
        for engine in ("so2dr", "resreu", "naive_tb"):
            t = modeled(engine, name, OOC_SZ, d, s_tb)
            rows.append((
                f"fig7/{name}/{engine}",
                t.total_serial * 1e6 / N_STEPS,
                f"modeled_tpu h2d={t.h2d:.3f} kernel={t.kernel:.3f} "
                f"odc={t.odc:.4f} d2h={t.d2h:.3f} "
                f"kmem={t.kernel_mem:.3f} kcomp={t.kernel_compute:.3f}",
            ))
    return rows


if __name__ == "__main__":
    emit(run())
