"""Paper Fig. 5: SO2DR performance across run-time configurations
(d x S_TB) on the out-of-core dataset — modeled with TPU-v5e constants.
"""
from repro.core.params import CodeSpec, feasible
from repro.core.analytic import TPU_V5E

from .common import N_STEPS, OOC_SZ, PAPER_BENCHMARKS, emit, modeled


def run():
    rows = []
    for name in PAPER_BENCHMARKS:
        from repro.core.stencil import get_stencil
        st = get_stencil(name)
        code = CodeSpec(sz=OOC_SZ, radius=st.radius, b_elem=4,
                        total_steps=N_STEPS, n_arrays=2)
        for d in (4, 8):
            for s_tb in (40, 80, 160, 320, 640):
                feas = feasible(code, TPU_V5E, d, s_tb)
                try:
                    t = modeled("so2dr", name, OOC_SZ, d, s_tb)
                except ValueError:
                    continue
                total = t.total_overlapped()
                rows.append((
                    f"fig5/{name}/d{d}/stb{s_tb}",
                    total * 1e6 / N_STEPS,  # us per time step
                    f"modeled_tpu total_s={total:.3f} feasible={feas} "
                    f"kernel_s={t.kernel:.3f} h2d_s={t.h2d:.3f}",
                ))
    return rows


if __name__ == "__main__":
    emit(run())
