"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json and emits, per (arch x shape x mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
per-device memory, and the roofline fraction.  Also nominates the three
hillclimb cells (worst fraction / most collective-bound / most
paper-representative).
"""
import glob
import json
import os
import sys

from .common import emit

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")

GENERATE_HINT = (
    "PYTHONPATH=src python -m repro.launch.dryrun --all   "
    "(writes artifacts/dryrun/*.json; see also --stencil for L2 cells)"
)


def load(art_dir=ART):
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if not r.get("skipped") and "roofline" in r:
            recs.append(r)
    return recs


def run(art_dir=None):
    rows = []
    for r in load(ART if art_dir is None else art_dir):
        roof = r["roofline"]
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        dom = roof["dominant"]
        frac = roof.get("roofline_fraction")
        name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
        t_dom = roof[f"t_{dom}"]
        derived = (
            f"dom={dom} t_comp={roof['t_compute']:.4f} "
            f"t_mem={roof['t_memory']:.4f} t_coll={roof['t_collective']:.4f} "
            f"useful={roof.get('useful_ratio', 0):.3f} "
            f"frac={frac if frac is None else round(frac, 5)} "
            f"mem_GB={r['memory']['temp_size_in_bytes'] / 1e9:.2f}"
        )
        rows.append((name, t_dom * 1e6, derived))
    return rows


def markdown_table(art_dir=ART):
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_coll | dominant "
        "| useful | roofline-frac | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(art_dir):
        roof = r["roofline"]
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        frac = roof.get("roofline_fraction")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {roof['t_compute']:.4f} | {roof['t_memory']:.4f} "
            f"| {roof['t_collective']:.4f} | {roof['dominant']} "
            f"| {roof.get('useful_ratio', 0):.3f} "
            f"| {'' if frac is None else format(frac, '.5f')} "
            f"| {r['memory']['temp_size_in_bytes'] / 1e9:.2f} |"
        )
    return "\n".join(lines)


def main(art_dir=None, argv=None) -> int:
    """CLI entry: exit 2 (not an empty table) when the artifacts are
    absent, pointing at the command that generates them."""
    art_dir = ART if art_dir is None else art_dir
    if not os.path.isdir(art_dir):
        print(f"roofline: artifact directory {art_dir!r} does not exist.\n"
              f"Generate it with:\n  {GENERATE_HINT}", file=sys.stderr)
        return 2
    recs = load(art_dir)
    if not recs:
        print(f"roofline: no usable dry-run records under {art_dir!r} "
              f"(empty directory or every record skipped).\n"
              f"Generate them with:\n  {GENERATE_HINT}", file=sys.stderr)
        return 2
    emit(run(art_dir))
    print()
    print(markdown_table(art_dir))
    return 0


if __name__ == "__main__":
    sys.exit(main())
