"""CI bench-gate: fail when planned wire bytes regress vs the baseline.

    PYTHONPATH=src python -m benchmarks.check_regression BENCH_plan.json

Compares the dry-run plan records produced by
``python -m benchmarks.run --dry-run --codec all --json BENCH_plan.json``
against the committed ``benchmarks/baselines.json``:

* a baseline key missing from the current run is an error (coverage
  regressed — an engine/codec stopped compiling);
* ``wire_bytes`` (and, for the sharded L2 records, ``ici_bytes``) above
  baseline by more than ``--tolerance`` (relative) is an error (a
  planner or codec change made transfers fatter);
* the deterministic op-count/cache metrics (``plan_ops``,
  ``stage_count``, ``shape_buckets`` — the kernel-compile ceiling of the
  lowered plan) must match the baseline *exactly*: they are integers
  derived from the plan and its lowering, so any drift is a real
  scheduling or bucketing change that deserves a deliberate baseline
  refresh;
* new keys are reported but allowed (refresh the baseline to start
  gating them).

Wire bytes are modeled at plan time, so the signal is deterministic:
any diff is a real scheduling/codec change, never measurement noise.
The tolerance only absorbs intentional sub-percent accounting tweaks.
Wall-clock numbers (``BENCH_exec.json``) never gate — they are uploaded
as a non-gating CI artifact only.

Exit code 0 = gate passes, 1 = regression, 2 = bad invocation.
"""

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baselines.json"

GATED_FIELDS = ("wire_bytes", "raw_bytes", "buffer_bytes", "ici_bytes")
# integer plan/lowering metrics: exact match, no tolerance.  The sharded
# (L2) records add the plan-derived per-round collective bytes and the
# ghost-wedge redundancy — deterministic functions of the schedule, so
# any drift is a real planner change that deserves a baseline refresh.
# The serving records (BENCH_serve.json vs baselines_serve.json) gate
# ``kernel_compiles`` the same way: total kernel traces across an M-job
# service trace are a deterministic function of the shared cache and
# bucket registry — one extra compile means warm-cache routing broke.
# Latency/throughput fields in those records are not listed here, so
# they stay non-gating artifacts.
# ``faults_injected``/``jobs_failed``/``slot_pool_in_use_after`` gate the
# chaos records: a clean run must stay clean (faults_injected=0 baselines
# never drift), an injected drill must fail exactly the scheduled jobs,
# and a faulted flush must leak zero slot leases.
EXACT_FIELDS = ("plan_ops", "stage_count", "shape_buckets",
                "collective_bytes_per_round", "redundant_elements",
                "halo_ops", "kernel_compiles", "faults_injected",
                "jobs_failed", "jobs_ok", "slot_pool_in_use_after")


def check(current: dict, baseline: dict, tolerance: float):
    """Return (errors, notes) comparing current plan records to baseline."""
    errors, notes = [], []
    for key, base in sorted(baseline.items()):
        cur = current.get(key)
        if cur is None:
            errors.append(f"{key}: present in baseline but missing from run")
            continue
        for field in GATED_FIELDS + EXACT_FIELDS:
            if field not in base:
                continue
            if field not in cur:
                # schema drift must not silently erode the gate
                errors.append(f"{key}: gated field {field!r} missing from run")
                continue
            if field in EXACT_FIELDS:
                if cur[field] != base[field]:
                    errors.append(
                        f"{key}: {field} changed {base[field]} -> "
                        f"{cur[field]} (deterministic metric; refresh "
                        f"baselines.json if intentional)")
                continue
            allowed = base[field] * (1.0 + tolerance)
            if cur[field] > allowed:
                errors.append(
                    f"{key}: {field} regressed {base[field]} -> {cur[field]} "
                    f"(+{(cur[field] / max(base[field], 1) - 1) * 100:.2f}%, "
                    f"tolerance {tolerance * 100:.1f}%)")
    for key in sorted(set(current) - set(baseline)):
        notes.append(f"{key}: new (not gated; refresh baselines.json to gate)")
    return errors, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_plan.json from the current run")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed baseline (default: benchmarks/baselines.json)")
    ap.add_argument("--tolerance", type=float, default=0.01,
                    help="allowed relative increase per gated field (default 1%%)")
    args = ap.parse_args(argv)

    try:
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        ap.error(str(e))

    errors, notes = check(current, baseline, args.tolerance)
    for note in notes:
        print(f"NOTE  {note}")
    for err in errors:
        print(f"FAIL  {err}")
    checked = len(set(baseline) & set(current))
    if errors:
        print(f"bench-gate: {len(errors)} regression(s) across "
              f"{checked} gated plans")
        return 1
    print(f"bench-gate: OK ({checked} plans within "
          f"{args.tolerance * 100:.1f}% of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
