"""CI bench-gate: fail when planned wire bytes regress vs the baseline.

    PYTHONPATH=src python -m benchmarks.check_regression BENCH_plan.json

Compares the dry-run plan records produced by
``python -m benchmarks.run --dry-run --codec all --json BENCH_plan.json``
against the committed ``benchmarks/baselines.json``:

* a baseline key missing from the current run is an error (coverage
  regressed — an engine/codec stopped compiling);
* ``wire_bytes`` (and, for the sharded L2 records, ``ici_bytes``) above
  baseline by more than ``--tolerance`` (relative) is an error (a
  planner or codec change made transfers fatter);
* the deterministic op-count/cache metrics (``plan_ops``,
  ``stage_count``, ``shape_buckets`` — the kernel-compile ceiling of the
  lowered plan) must match the baseline *exactly*: they are integers
  derived from the plan and its lowering, so any drift is a real
  scheduling or bucketing change that deserves a deliberate baseline
  refresh;
* new keys are reported but allowed (refresh the baseline to start
  gating them).

Wire bytes are modeled at plan time, so the signal is deterministic:
any diff is a real scheduling/codec change, never measurement noise.
The tolerance only absorbs intentional sub-percent accounting tweaks.
Wall-clock numbers (``BENCH_exec.json``) never gate — they are uploaded
as a non-gating CI artifact only.

``--profile BENCH_profile.json`` gates *fit sanity* of a calibrated
DeviceProfile (the CI ``calibrate`` job): every fitted rate must be
strictly positive and every fit residual under ``--residual-ceiling``.
The measured values themselves are machine-dependent and never gate.

Exit code 0 = gate passes, 1 = regression, 2 = bad invocation.
"""

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baselines.json"

GATED_FIELDS = ("wire_bytes", "raw_bytes", "buffer_bytes", "ici_bytes",
                "ici_wire_bytes")
# integer plan/lowering metrics: exact match, no tolerance.  The sharded
# (L2) records add the plan-derived per-round collective bytes and the
# ghost-wedge redundancy — deterministic functions of the schedule, so
# any drift is a real planner change that deserves a baseline refresh.
# The serving records (BENCH_serve.json vs baselines_serve.json) gate
# ``kernel_compiles`` the same way: total kernel traces across an M-job
# service trace are a deterministic function of the shared cache and
# bucket registry — one extra compile means warm-cache routing broke.
# Latency/throughput fields in those records are not listed here, so
# they stay non-gating artifacts.
# ``faults_injected``/``jobs_failed``/``slot_pool_in_use_after`` gate the
# chaos records: a clean run must stay clean (faults_injected=0 baselines
# never drift), an injected drill must fail exactly the scheduled jobs,
# and a faulted flush must leak zero slot leases.
# The hierarchical (hier/*) records add ``inner_chunks`` (the derived
# nested-streaming depth for the fixed 1 GiB device budget) and
# ``codec_ops`` (HaloCompress/Decompress sites) plus the per-round
# *wire* collective rate — all plan-derived integers.
EXACT_FIELDS = ("plan_ops", "stage_count", "shape_buckets",
                "collective_bytes_per_round",
                "collective_wire_bytes_per_round", "redundant_elements",
                "halo_ops", "kernel_compiles", "faults_injected",
                "jobs_failed", "jobs_ok", "slot_pool_in_use_after",
                "inner_chunks", "codec_ops")


def check(current: dict, baseline: dict, tolerance: float):
    """Return (errors, notes) comparing current plan records to baseline."""
    errors, notes = [], []
    for key, base in sorted(baseline.items()):
        cur = current.get(key)
        if cur is None:
            errors.append(f"{key}: present in baseline but missing from run")
            continue
        for field in GATED_FIELDS + EXACT_FIELDS:
            if field not in base:
                continue
            if field not in cur:
                # schema drift must not silently erode the gate
                errors.append(f"{key}: gated field {field!r} missing from run")
                continue
            if field in EXACT_FIELDS:
                if cur[field] != base[field]:
                    errors.append(
                        f"{key}: {field} changed {base[field]} -> "
                        f"{cur[field]} (deterministic metric; refresh "
                        f"baselines.json if intentional)")
                continue
            allowed = base[field] * (1.0 + tolerance)
            if cur[field] > allowed:
                errors.append(
                    f"{key}: {field} regressed {base[field]} -> {cur[field]} "
                    f"(+{(cur[field] / max(base[field], 1) - 1) * 100:.2f}%, "
                    f"tolerance {tolerance * 100:.1f}%)")
    for key in sorted(set(current) - set(baseline)):
        notes.append(f"{key}: new (not gated; refresh baselines.json to gate)")
    return errors, notes


def check_profile(profile: dict, residual_ceiling: float):
    """Fit-sanity gate for a calibrated DeviceProfile (CI `calibrate`
    job): every fitted rate strictly positive, latency non-negative,
    every relative-RMS fit residual under the ceiling.  Returns a list
    of errors (empty = sane).  Measured *values* are machine-dependent
    and never gate — only the shape of the fit does."""
    errors = []
    if profile.get("schema_version") != 1:
        errors.append(f"profile: unsupported schema_version "
                      f"{profile.get('schema_version')!r} (expected 1)")
        return errors
    hw = profile.get("hardware", {})
    for term in ("bw_intc", "bw_dmem", "peak_vpu_flops"):
        if not hw.get(term, 0) > 0:
            errors.append(f"profile: fitted hardware.{term} not positive: "
                          f"{hw.get(term)!r}")
    if hw.get("t_ici_latency", 0) < 0:
        errors.append(f"profile: hardware.t_ici_latency negative: "
                      f"{hw['t_ici_latency']!r}")
    for impl, terms in sorted(profile.get("kernel_terms", {}).items()):
        for term in ("bw_eff", "flops_eff"):
            if not terms.get(term, 0) > 0:
                errors.append(f"profile: kernel_terms[{impl!r}].{term} "
                              f"not positive: {terms.get(term)!r}")
    for codec, thr in sorted(profile.get("codec_throughput", {}).items()):
        for term in ("encode_bps", "decode_bps"):
            if not thr.get(term, 0) > 0:
                errors.append(f"profile: codec_throughput[{codec!r}].{term} "
                              f"not positive: {thr.get(term)!r}")
    for name, resid in sorted(profile.get("residuals", {}).items()):
        if not resid >= 0:
            errors.append(f"profile: residual {name} negative: {resid!r}")
        elif resid > residual_ceiling:
            errors.append(f"profile: residual {name} = {resid:.3f} exceeds "
                          f"ceiling {residual_ceiling} (fit did not "
                          f"converge; widen the size ladder or raise "
                          f"--residual-ceiling deliberately)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", default=None,
                    help="BENCH_plan.json from the current run")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed baseline (default: benchmarks/baselines.json)")
    ap.add_argument("--tolerance", type=float, default=0.01,
                    help="allowed relative increase per gated field (default 1%%)")
    ap.add_argument("--profile", metavar="PATH", default=None,
                    help="gate fit sanity of a calibrated DeviceProfile "
                         "JSON (benchmarks/calibrate.py output) instead "
                         "of / in addition to the plan records")
    ap.add_argument("--residual-ceiling", type=float, default=5.0,
                    help="max allowed relative-RMS fit residual for "
                         "--profile (default %(default)s)")
    args = ap.parse_args(argv)

    if args.current is None and args.profile is None:
        ap.error("nothing to gate: pass BENCH_plan.json and/or --profile")

    errors, notes = [], []
    if args.profile is not None:
        try:
            with open(args.profile) as f:
                profile = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            ap.error(str(e))
        errors += check_profile(profile, args.residual_ceiling)
        if not errors:
            notes.append(f"profile {profile.get('profile_id')}: fit sane "
                         f"(residuals <= {args.residual_ceiling})")
    if args.current is None:
        for note in notes:
            print(f"NOTE  {note}")
        for err in errors:
            print(f"FAIL  {err}")
        if errors:
            print(f"bench-gate: {len(errors)} profile error(s)")
            return 1
        print("bench-gate: OK (profile fit sane)")
        return 0

    try:
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        ap.error(str(e))

    plan_errors, plan_notes = check(current, baseline, args.tolerance)
    errors += plan_errors
    notes += plan_notes
    for note in notes:
        print(f"NOTE  {note}")
    for err in errors:
        print(f"FAIL  {err}")
    checked = len(set(baseline) & set(current))
    if errors:
        print(f"bench-gate: {len(errors)} regression(s) across "
              f"{checked} gated plans")
        return 1
    print(f"bench-gate: OK ({checked} plans within "
          f"{args.tolerance * 100:.1f}% of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
