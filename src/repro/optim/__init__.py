from .adamw import AdamW, OptState  # noqa: F401
