"""AdamW with global-norm clipping, warmup-cosine schedule and a
moment-dtype knob (bf16 moments halve optimizer HBM — the ZeRO-style
memory trick the 400B dry-run relies on).

Optimizer state is a pytree mirroring params, so pjit shards it with the
same PartitionSpecs as the parameters (ZeRO-3 when params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]


class OptState(NamedTuple):
    step: jnp.ndarray     # ()
    mu: Any               # pytree like params
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32   # bf16 halves optimizer memory

    def init(self, params) -> OptState:
        def z(p):
            return jnp.zeros_like(p, dtype=self.moment_dtype)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
        )

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gn = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * self.b1 + gf * (1 - self.b1)
            v32 = v.astype(jnp.float32) * self.b2 + jnp.square(gf) * (1 - self.b2)
            mhat = m32 / b1c
            vhat = v32 / b2c
            pf = p.astype(jnp.float32)
            pnew = pf - lr * (mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * pf)
            return (
                pnew.astype(p.dtype),
                m32.astype(self.moment_dtype),
                v32.astype(self.moment_dtype),
            )

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step=step, mu=new_m, nu=new_v)
