"""Cross-job pipelined scheduling for :class:`~repro.serve.service.StencilService`.

The paper's SO2DR schedule hides transfer under compute *within* one
job; a warm service can do strictly better by interleaving the
per-(round, chunk) stage programs of M concurrent jobs, so one job's
H2D rides under another job's kernels — overlap a single job's barrier
structure can never express.

Soundness of the round-robin merge: each job's stages stay in its own
plan order, so every earlier stage of job *j* (including its HostCommit
barriers) has executed before any later stage of *j* is issued.  The
double-buffered prefetch discipline from
:class:`~repro.core.lower.CompiledPlan` carries over unchanged — a
stage's prefetchable prefix (H2D + host-side Compress) is issued early
only when the stage is a chunk stage, never across its own job's
barrier, and always against its own job's runtime.

Admission ordering is deadline-aware shortest-predicted-first: the
dry-run cost model (:func:`repro.core.autotune.predicted_makespan`)
prices each job with zero device work, jobs with deadlines sort ahead
of best-effort jobs, and ties break on job id for determinism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.analytic import Hardware
from repro.core.autotune import pipeline_makespan, stage_costs
from repro.core.faults import InjectedFault, consult
from repro.core.lower import CompiledPlan, ExecStats, OP_TAGS, SlotPool
from repro.core.recovery import PlanExecutionError

__all__ = ["ScheduledJob", "admission_order", "interleave_stages",
           "modeled_makespan", "run_interleaved"]

_KERNEL_TAG = OP_TAGS.index("FusedKernel")


@dataclasses.dataclass
class ScheduledJob:
    """One admitted job: its compiled plan, input domain, and the
    dry-run price admission sorted on."""

    job_id: int
    compiled: CompiledPlan
    x: np.ndarray
    predicted_s: float
    deadline: Optional[float] = None
    # fault-injection hooks (None in production): consulted before every
    # bound op of this job's stages, retried under ``retry``
    injector: Optional[object] = None
    retry: Optional[object] = None


def admission_order(jobs: Sequence[ScheduledJob]) -> List[ScheduledJob]:
    """Deadline-aware shortest-predicted-makespan-first admission.

    Jobs carrying a deadline run before best-effort jobs and among
    themselves by earliest deadline; within a deadline class the
    cheapest predicted job goes first (SJF minimizes mean latency);
    job id breaks the remaining ties deterministically."""
    return sorted(jobs, key=lambda j: (
        j.deadline if j.deadline is not None else float("inf"),
        j.predicted_s, j.job_id))


def interleave_stages(jobs: Sequence[ScheduledJob],
                      ) -> List[Tuple[ScheduledJob, int]]:
    """Round-robin merge of the jobs' stage programs.

    One stage per job per cycle, in admission order, preserving each
    job's internal stage order — the schedule both the makespan model
    and :func:`run_interleaved` walk."""
    merged: List[Tuple[ScheduledJob, int]] = []
    cursors = [0] * len(jobs)
    remaining = sum(len(j.compiled.stages) for j in jobs)
    while remaining:
        for i, job in enumerate(jobs):
            if cursors[i] < len(job.compiled.stages):
                merged.append((job, cursors[i]))
                cursors[i] += 1
                remaining -= 1
    return merged


def modeled_makespan(jobs: Sequence[ScheduledJob], hw: Hardware,
                     interleaved: bool = True, profile=None) -> float:
    """Dry-run makespan of the job set on the three-engine pipeline.

    ``interleaved=True`` prices the round-robin merge; ``False`` prices
    the same jobs back-to-back — the comparison the service's perf win
    is asserted against (no device work either way).  ``profile`` (a
    :class:`~repro.core.calibrate.DeviceProfile` or a path) substitutes
    calibrated constants for ``hw``."""
    if profile is not None:
        from repro.core.calibrate import resolve_hardware

        hw = resolve_hardware(profile)
    costed = {j.job_id: stage_costs(j.compiled.plan, hw) for j in jobs}
    if interleaved:
        schedule = [(job.job_id, costed[job.job_id][s])
                    for job, s in interleave_stages(jobs)]
        return pipeline_makespan(schedule)
    return sum(pipeline_makespan((j.job_id, sc) for sc in costed[j.job_id])
               for j in jobs)


def run_interleaved(jobs: Sequence[ScheduledJob],
                    slot_pool: Optional[SlotPool] = None,
                    ) -> List[Tuple[ScheduledJob, Optional[np.ndarray],
                                    ExecStats, float,
                                    Optional[PlanExecutionError]]]:
    """Execute the merged schedule; one result tuple per job, in the
    given (admission) order: ``(job, host_out, exec_stats, latency_s,
    fault)``.

    Each job gets its own :class:`~repro.core.lower._Runtime` (slot
    storage leased from ``slot_pool`` when given); the merged walk
    applies the double-buffered prefetch rule across the *merged*
    sequence, so job B's H2D is issued while job A's kernels are still
    in flight — the cross-job analogue of the paper's N_strm = 3
    overlap.  Latency is stamped when a job's last stage retires (its
    final barrier has drained its staged writes).

    Graceful degradation: a job whose injector raises a terminal fault
    is *isolated* — its leased slots are released on the spot, its
    remaining merged entries are skipped, and it comes back with
    ``host_out=None`` and the typed ``fault`` attached — while every
    other job's stage walk continues untouched (property-tested: the
    survivors stay bit-identical to a fault-free run)."""
    perf = time.perf_counter
    runtimes = {}
    try:
        for job in jobs:
            runtimes[job.job_id] = job.compiled.runtime(job.x, slot_pool)
        merged = interleave_stages(jobs)
        n = len(merged)
        prefetched = [False] * n
        wall: Dict[int, List[float]] = {
            j.job_id: [0.0] * len(OP_TAGS) for j in jobs}
        counts: Dict[int, List[int]] = {
            j.job_id: [0] * len(OP_TAGS) for j in jobs}
        snap: Dict[int, Tuple[int, int]] = {}   # job -> (hits, misses) deltas
        inj0: Dict[int, Tuple[int, int]] = {}   # job -> (faults, retries) at t0
        for j in jobs:
            snap[j.job_id] = (0, 0)
            inj0[j.job_id] = ((j.injector.faults_injected, j.injector.retries)
                              if j.injector is not None else (0, 0))
        latency: Dict[int, float] = {}
        failed: Dict[int, PlanExecutionError] = {}
        last_stage = {j.job_id: len(j.compiled.stages) - 1 for j in jobs}

        def run(job: ScheduledJob, ops) -> None:
            rt = runtimes[job.job_id]
            w, c = wall[job.job_id], counts[job.job_id]
            cache = job.compiled.cache
            h0, m0 = cache.snapshot()
            try:
                for tag, fn, rnd, chunk in ops:
                    if job.injector is not None:
                        consult(job.injector, job.retry, rnd, chunk,
                                OP_TAGS[tag])
                    t0 = perf()
                    fn(rt)
                    w[tag] += perf() - t0
                    c[tag] += 1
            finally:
                h1, m1 = cache.snapshot()
                dh, dm = snap[job.job_id]
                snap[job.job_id] = (dh + h1 - h0, dm + m1 - m0)

        def try_run(job: ScheduledJob, ops) -> bool:
            """Run a job's ops; on a terminal injected fault, isolate the
            job (slots back to the pool immediately) and record the typed
            error.  Returns False when the job just died."""
            try:
                run(job, ops)
                return True
            except InjectedFault as f:
                rt = runtimes[job.job_id]
                failed[job.job_id] = PlanExecutionError(
                    f"job {job.job_id} failed at round={f.round} "
                    f"chunk={f.chunk} op={f.op_class}: {f.kind}",
                    fault=f, last_committed_round=rt.committed_round)
                CompiledPlan.release_runtime(rt, slot_pool)
                runtimes[job.job_id] = None
                latency[job.job_id] = perf() - t_start
                return False

        t_start = perf()
        for m, (job, s) in enumerate(merged):
            if job.job_id in failed:
                continue
            stage = job.compiled.stages[s]
            if stage.key is None:           # the job's HostCommit barrier
                try_run(job, stage.ops)
            else:
                # prefetch the next merged entry's transfer prefix (on
                # *its* job's runtime) under this stage's kernels; a
                # barrier entry prefetches nothing — its own job's host
                # rows are about to change
                if m + 1 < n:
                    nxt_job, nxt_s = merged[m + 1]
                    if nxt_job.job_id not in failed:
                        nxt = nxt_job.compiled.stages[nxt_s]
                        if nxt.key is not None and try_run(nxt_job,
                                                           nxt.prefetch):
                            prefetched[m + 1] = True
                try_run(job, stage.rest if prefetched[m] else stage.ops)
            if job.job_id not in failed and s == last_stage[job.job_id]:
                runtimes[job.job_id].commit()   # planner-forgot-barrier no-op
                latency[job.job_id] = perf() - t_start

        out = []
        for job in jobs:
            c, w = counts[job.job_id], wall[job.job_id]
            dh, dm = snap[job.job_id]
            if job.injector is not None:
                df = job.injector.faults_injected - inj0[job.job_id][0]
                dr = job.injector.retries - inj0[job.job_id][1]
            else:
                df = dr = 0
            stats = ExecStats(
                executor="pipelined",
                kernel_impl=job.compiled.kernel_impl,
                op_counts={OP_TAGS[i]: v for i, v in enumerate(c) if v},
                op_wall_s={OP_TAGS[i]: w[i] for i, v in enumerate(c) if v},
                kernel_calls=c[_KERNEL_TAG],
                shape_buckets=job.compiled.shape_buckets,
                kernel_compiles=dm,
                kernel_cache_hits=dh,
                stage_count=sum(1 for st in job.compiled.stages
                                if st.key is not None),
                lower_s=job.compiled.lower_s,
                wall_s=latency[job.job_id],
                faults_injected=df,
                retries=dr,
            )
            fault = failed.get(job.job_id)
            rt = runtimes[job.job_id]
            out.append((job, rt.host if fault is None else None, stats,
                        latency[job.job_id], fault))
        return out
    finally:
        for job in jobs:
            rt = runtimes.get(job.job_id)
            if rt is not None:
                CompiledPlan.release_runtime(rt, slot_pool)
