"""Stencil-as-a-service: a persistent plan server with warm caches.

A :class:`StencilService` owns, for its whole lifetime:

* one :class:`~repro.core.lower.KernelCache` — kernel signatures
  compiled for any job stay warm for every later job;
* one :class:`~repro.core.lower.BucketRegistry` — cross-job shape
  buckets, so a job with an *unseen* shape that fits an existing bucket
  lowers onto already-compiled kernel signatures (zero new traces on a
  warm cache);
* one :class:`~repro.core.lower.SlotPool` — device slot storage leased
  per run and returned at job retirement instead of reallocated per
  plan.

Jobs are specified as ``(shape, stencil, steps, codec, deadline)``
(:class:`StencilJob`), compiled through the existing
``PlanBuilder``/:func:`~repro.core.lower.lower` path at submit time,
priced by the dry-run cost model
(:func:`~repro.core.autotune.predicted_makespan`), and executed in
deadline-aware shortest-predicted-first order by the cross-job
pipelined scheduler (:mod:`repro.serve.scheduler`) on :meth:`flush` —
M interleaved jobs finish sooner than the same jobs back-to-back
because one job's transfers hide under another job's kernels.

``submit`` is thread-safe (compilation runs outside the queue lock;
the kernel cache and bucket registry take their own locks), so a
server loop can admit jobs from concurrent request handlers and flush
from a single executor thread.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional, Tuple

import numpy as np

from repro.core.analytic import Hardware, TPU_V5E
from repro.core.autotune import predicted_makespan, predicted_sharded_makespan
from repro.core.lower import (
    BucketRegistry, CompiledPlan, ExecStats, KernelCache, SlotPool, lower,
)
from repro.core.oocore import compile_plan
from repro.core.plan import TransferStats
from repro.core.stencil import get_stencil

from .scheduler import (
    ScheduledJob, admission_order, modeled_makespan, run_interleaved,
)

__all__ = ["StencilJob", "JobResult", "StencilService"]


@dataclasses.dataclass(frozen=True)
class StencilJob:
    """One service request: what to compute and how urgently.

    ``shape`` is the *framed* host domain ``(Y, X)``; ``deadline`` is a
    relative budget in seconds (``None`` = best effort, runs after all
    deadline jobs).  The engine knobs default to the paper's SO2DR
    configuration; ``s_tb=None`` fuses all ``steps`` into one
    temporal block."""

    shape: Tuple[int, int]
    stencil: str
    steps: int
    codec: str = "identity"
    deadline: Optional[float] = None
    engine: str = "so2dr"
    d: int = 4
    s_tb: Optional[int] = None
    k_on: int = 2
    # fault-injection schedule (tests/chaos drills only): a
    # repro.core.faults.FaultPlan consulted at every op site of this
    # job's stages, with transient faults retried under ``retry``
    faults: Optional[object] = None
    retry: Optional[object] = None


@dataclasses.dataclass
class JobResult:
    """What :meth:`StencilService.flush` returns per job, in execution
    order.

    ``status`` is ``"ok"`` or ``"failed"``; a failed job carries the
    typed :class:`~repro.core.recovery.PlanExecutionError` in ``fault``
    (with the injected cause and last committed round) and ``out=None``
    — its slots were released the moment it died, and the rest of the
    batch completed normally."""

    job_id: int
    out: Optional[np.ndarray]
    stats: TransferStats          # plan-side accounting
    exec_stats: ExecStats         # execution-side counters (per job)
    predicted_s: float            # dry-run price admission sorted on
    latency_s: float              # flush start -> this job's last commit
    status: str = "ok"
    fault: Optional[BaseException] = None


class StencilService:
    """Long-lived stencil server amortizing compilation across jobs.

    ``profile`` — a :class:`~repro.core.calibrate.DeviceProfile` (or a
    path to one): admission then prices ``predicted_makespan`` with the
    profile's *calibrated* constants instead of the hand-entered ``hw``
    table, so deadline decisions are trustworthy on the chip the
    service actually landed on.  When both are given the profile wins."""

    def __init__(self, hw: Hardware = TPU_V5E, policy=None, profile=None):
        from repro.core.calibrate import DeviceProfile, resolve_hardware

        if isinstance(profile, str):
            profile = DeviceProfile.load(profile)
        self.profile = profile
        self.hw = hw if profile is None else resolve_hardware(profile)
        self.policy = policy
        self.kernel_cache = KernelCache()
        self.buckets = BucketRegistry()
        self.slot_pool = SlotPool()
        self._lock = threading.Lock()
        self._queue: List[ScheduledJob] = []
        self._next_id = 0
        self.jobs_submitted = 0
        self.jobs_completed = 0
        self.jobs_failed = 0
        # the admission order of the last flush (ScheduledJobs), kept so
        # callers can re-price the batch (modeled interleaved vs solo)
        self.last_admission: List[ScheduledJob] = []
        self.exec_stats = ExecStats(executor="service")   # lifetime merge

    # -- compilation ---------------------------------------------------

    def compile_job(self, job: StencilJob, itemsize: int = 4) -> CompiledPlan:
        """Compile a job through the warm caches (no execution).

        The plan comes from the existing engine planners; lowering
        shares the service's kernel cache *and* routes band heights
        through the cross-job bucket registry, so an unseen shape that
        fits an existing bucket compiles zero new kernels."""
        Y, X = job.shape
        st = get_stencil(job.stencil)
        s_tb = job.steps if job.s_tb is None else job.s_tb
        plan = compile_plan(job.engine, st, Y, X, job.steps, job.d,
                            s_tb, job.k_on, itemsize=itemsize,
                            codec=None if job.codec == "identity"
                            else job.codec)
        return lower(plan, policy=self.policy,
                     kernel_cache=self.kernel_cache,
                     bucket_registry=self.buckets)

    # -- admission -----------------------------------------------------

    def submit(self, job: StencilJob, x: np.ndarray) -> int:
        """Admit a job: compile (warm caches), price it with the
        dry-run model, enqueue.  Thread-safe; returns the job id."""
        compiled = self.compile_job(job, itemsize=x.dtype.itemsize)
        predicted = predicted_makespan(compiled.plan, self.hw)
        injector = job.faults.injector() if job.faults is not None else None
        with self._lock:
            job_id = self._next_id
            self._next_id += 1
            self._queue.append(ScheduledJob(
                job_id=job_id, compiled=compiled, x=x,
                predicted_s=predicted, deadline=job.deadline,
                injector=injector, retry=job.retry))
            self.jobs_submitted += 1
        return job_id

    # -- execution -----------------------------------------------------

    def flush(self) -> List[JobResult]:
        """Run every queued job through the cross-job pipeline.

        Jobs execute in deadline-aware shortest-predicted-first
        admission order, their stage programs interleaved under the
        double-buffered discipline; results come back in that execution
        order.  Per-job ``ExecStats`` also merge into the service's
        lifetime ``exec_stats``.  A terminally-faulted job degrades
        gracefully: it returns ``status="failed"`` with the fault
        attached and never poisons the rest of the batch."""
        with self._lock:
            batch, self._queue = self._queue, []
        ordered = admission_order(batch)
        self.last_admission = ordered
        results: List[JobResult] = []
        n_ok = 0
        for job, host, stats, latency, fault in run_interleaved(
                ordered, slot_pool=self.slot_pool):
            self.exec_stats.merge(stats)
            results.append(JobResult(
                job_id=job.job_id, out=host,
                stats=job.compiled.plan.stats(), exec_stats=stats,
                predicted_s=job.predicted_s, latency_s=latency,
                status="ok" if fault is None else "failed", fault=fault))
            n_ok += fault is None
        with self._lock:
            self.jobs_completed += n_ok
            self.jobs_failed += len(results) - n_ok
        return results

    def run_solo(self, job: StencilJob, x: np.ndarray) -> JobResult:
        """Run one job immediately, alone, under the same
        double-buffered discipline (the back-to-back baseline the
        interleaved makespan is compared against).  Bypasses the queue;
        still uses every warm cache."""
        compiled = self.compile_job(job, itemsize=x.dtype.itemsize)
        predicted = predicted_makespan(compiled.plan, self.hw)
        host, stats, exec_stats = compiled.execute(
            x, pipeline=True, slot_pool=self.slot_pool)
        exec_stats.executor = "pipelined"
        self.exec_stats.merge(exec_stats)
        with self._lock:
            job_id = self._next_id
            self._next_id += 1
            self.jobs_submitted += 1
            self.jobs_completed += 1
        return JobResult(job_id=job_id, out=host, stats=stats,
                         exec_stats=exec_stats, predicted_s=predicted,
                         latency_s=exec_stats.wall_s)

    def run_sharded(self, plan, x: np.ndarray,
                    faults=None, retry=None) -> JobResult:
        """Run a sharded or hierarchical plan on the fake-device
        simulator through the service's warm state.

        The lockstep simulator shares the service ``kernel_cache``
        (masked inner signatures stay warm across jobs) and — for
        hierarchical plans — leases every nested chunk slot from the
        service ``slot_pool``, releasing on retirement *and* on fault
        paths: after a mid-flush failure
        :meth:`~repro.core.lower.SlotPool.assert_balanced` still holds,
        which ``tests/test_service.py`` pins.  A terminal injected
        fault degrades exactly like a queued job: ``status="failed"``
        with the typed error attached, accounting from the plan."""
        from repro.core.executor import ShardedSimExecutor
        from repro.core.recovery import PlanExecutionError

        ex = ShardedSimExecutor(slot_pool=self.slot_pool,
                                kernel_cache=self.kernel_cache)
        predicted = predicted_sharded_makespan(plan, self.hw)
        injector = faults.injector() if faults is not None else None
        with self._lock:
            job_id = self._next_id
            self._next_id += 1
            self.jobs_submitted += 1
        host: Optional[np.ndarray] = None
        fault: Optional[BaseException] = None
        try:
            host, _ = ex.execute(plan, x, injector=injector, retry=retry)
        except PlanExecutionError as e:
            fault = e
        exec_stats = ex.exec_stats or ExecStats(executor=ex.name)
        self.exec_stats.merge(exec_stats)
        with self._lock:
            if fault is None:
                self.jobs_completed += 1
            else:
                self.jobs_failed += 1
        return JobResult(job_id=job_id, out=host, stats=plan.stats(),
                         exec_stats=exec_stats, predicted_s=predicted,
                         latency_s=exec_stats.wall_s,
                         status="ok" if fault is None else "failed",
                         fault=fault)

    # -- pricing / introspection --------------------------------------

    def modeled_makespan(self, jobs: Optional[List[ScheduledJob]] = None,
                         interleaved: bool = True) -> float:
        """Dry-run makespan of a batch (default: the last flushed one)
        on this service's hardware model — interleaved or
        back-to-back."""
        jobs = self.last_admission if jobs is None else jobs
        return modeled_makespan(jobs, self.hw, interleaved=interleaved)

    def service_stats(self) -> dict:
        """Lifetime counters: warm-cache health + pool reuse."""
        hits, misses = self.kernel_cache.snapshot()
        return {
            "profile_id": (self.profile.profile_id
                           if self.profile is not None else None),
            "jobs_submitted": self.jobs_submitted,
            "jobs_completed": self.jobs_completed,
            "jobs_failed": self.jobs_failed,
            "kernel_signatures": len(self.kernel_cache),
            "kernel_cache_hits": hits,
            "kernel_compiles": misses,
            "shape_buckets": len(self.buckets),
            "slot_pool": self.slot_pool.stats(),
        }
