"""Stencil-as-a-service: persistent plan server + cross-job scheduler.

:class:`StencilService` keeps one warm kernel cache, shape-bucket
registry, and device slot pool alive across jobs; the scheduler
interleaves concurrent jobs' stage programs so one job's transfers
hide under another's kernels (see :mod:`repro.serve.service`).
"""
from .scheduler import (  # noqa: F401
    ScheduledJob, admission_order, interleave_stages, modeled_makespan,
    run_interleaved,
)
from .service import JobResult, StencilJob, StencilService  # noqa: F401

__all__ = [
    "StencilService", "StencilJob", "JobResult",
    "ScheduledJob", "admission_order", "interleave_stages",
    "modeled_makespan", "run_interleaved",
]
