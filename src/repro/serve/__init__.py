from .decode import make_prefill, make_decode_step  # noqa: F401
