"""LM serving steps: batched prefill + single-token decode.

Legacy module, kept only for ``greedy_generate`` (the system test's
end-to-end LM decode check); the serving layer proper is the stencil
service in :mod:`repro.serve.service`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_prefill", "make_decode_step", "greedy_generate"]


def make_prefill(model):
    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill


def make_decode_step(model):
    def step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)
    return step


def greedy_generate(model, params, batch, max_new: int, max_len: int):
    """Batched greedy decoding driver (examples/serve_lm.py)."""
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    cache = model.init_cache(B, max_len)
    logits, cache = model.prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    step = jax.jit(model.decode_step)
    for i in range(max_new - 1):
        logits, cache = step(params, tok, jnp.int32(S + i), cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
