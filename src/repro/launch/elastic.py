"""Elastic re-planning: reshard a checkpoint onto a different mesh.

At 1000+ nodes, slices come and go; a framework must restart on whatever
device count is healthy.  Because checkpoints store full (unsharded)
arrays and shardings are *derived* (param_specs is a pure function of
config + mesh), elasticity reduces to: rebuild the mesh, re-derive specs,
device_put the restored leaves.  ``replan`` returns the new shardings;
``tests/test_elastic.py`` exercises a 4-device -> 2-device restart in a
subprocess.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import ArchConfig
from .sharding import named, param_specs

__all__ = ["replan", "reshard_restored"]


def replan(cfg: ArchConfig, params_shape: Any, mesh) -> Any:
    """Derive shardings for an arbitrary (possibly new) mesh."""
    return named(mesh, param_specs(cfg, params_shape, mesh))


def reshard_restored(restored: Any, shardings: Any) -> Any:
    """Place host (numpy) leaves from CheckpointManager.restore onto the
    new mesh."""
    return jax.tree.map(jax.device_put, restored, shardings)
