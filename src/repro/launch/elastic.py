"""Elastic re-planning: survive topology change mid-run.

Two layers of elasticity live here:

* **Checkpoint resharding** (the original LM half): because checkpoints
  store full (unsharded) arrays and shardings are *derived*
  (``param_specs`` is a pure function of config + mesh), an elastic
  restart reduces to rebuild mesh → re-derive specs → ``device_put`` the
  restored leaves (``replan``/``reshard_restored``;
  ``tests/test_elastic.py`` exercises a 4-device → 2-device restart).

* **Plan-IR elasticity** (wired to :mod:`repro.core.shard`): a
  :class:`~repro.core.plan.ShardedPlan` commits host state once at its
  final store phase, so :func:`run_elastic_sharded` executes it as a
  sequence of *one-round continuation plans* — after every round the
  cropped owned regions land on the host, which is exactly the
  ``HostCommit`` barrier state of the single-device engines.  On an
  injected :class:`~repro.core.faults.RankLossFault` (a pod-slice
  preemption), :func:`shrink_mesh` drops a mesh row/column,
  :func:`replan_sharded` compiles the remaining rounds on the surviving
  mesh, and only the faulted round is redone — **a preemption costs one
  round** of transfers, never the run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.faults import FaultInjector, FaultPlan, InjectedFault, \
    RankLossFault, RetryPolicy
from repro.core.plan import ShardedPlan
from repro.core.recovery import PlanExecutionError, plan_fingerprint
from repro.core.shard import compile_sharded
from .sharding import named, param_specs

__all__ = ["replan", "reshard_restored",
           "ElasticReport", "shrink_mesh", "replan_sharded",
           "run_elastic_sharded"]


def replan(cfg: ArchConfig, params_shape: Any, mesh) -> Any:
    """Derive shardings for an arbitrary (possibly new) mesh."""
    return named(mesh, param_specs(cfg, params_shape, mesh))


def reshard_restored(restored: Any, shardings: Any) -> Any:
    """Place host (numpy) leaves from CheckpointManager.restore onto the
    new mesh."""
    return jax.tree.map(jax.device_put, restored, shardings)


# --------------------------------------------------------------------------
# Plan-IR elasticity: ShardedPlan × rank loss → re-plan on the survivors.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ElasticReport:
    """What an elastic run cost: ``rounds_executed`` counts dispatched
    round attempts (each moves one round of shard transfers), so
    ``extra_rounds`` — attempts beyond the fault-free count — is exactly
    the transfer price of the preemptions survived."""

    rounds_total: int
    rounds_executed: int
    replans: int
    mesh_history: Tuple[Tuple[int, int], ...]
    faults_injected: int
    fingerprint: str

    @property
    def extra_rounds(self) -> int:
        return self.rounds_executed - self.rounds_total


def shrink_mesh(mesh_shape: Tuple[int, int],
                lost_rank: int) -> Tuple[int, int]:
    """The surviving mesh after losing one rank: drop the mesh row
    holding it (uniform shards make which row irrelevant), or a column
    when the mesh is a single row."""
    n_row, n_col = mesh_shape
    if lost_rank < 0 or lost_rank >= n_row * n_col:
        raise ValueError(f"rank {lost_rank} not in mesh {mesh_shape}")
    if n_row > 1:
        return (n_row - 1, n_col)
    if n_col > 1:
        return (n_row, n_col - 1)
    raise ValueError("cannot lose the only rank of a (1, 1) mesh")


def replan_sharded(plan: ShardedPlan, from_round: int,
                   mesh_shape: Optional[Tuple[int, int]] = None,
                   lost_rank: Optional[int] = None) -> ShardedPlan:
    """The continuation plan: the rounds at or after ``from_round`` on
    ``mesh_shape`` (default: the surviving mesh after ``lost_rank``
    died, or the original mesh).  Feasibility is re-checked by
    :func:`~repro.core.shard.compile_sharded` — a domain that no longer
    divides the shrunken mesh raises, exactly like a fresh compile."""
    if mesh_shape is None:
        mesh_shape = shrink_mesh(plan.mesh_shape, lost_rank) \
            if lost_rank is not None else plan.mesh_shape
    remaining = (plan.rounds - from_round) * plan.k_ici
    if remaining <= 0:
        raise ValueError(f"nothing to replan: from_round={from_round} of "
                         f"{plan.rounds} rounds")
    return compile_sharded(plan.stencil, plan.Y, plan.X, remaining,
                           plan.k_ici, mesh_shape, itemsize=plan.itemsize)


def run_elastic_sharded(plan: ShardedPlan, x: np.ndarray,
                        faults: Optional[FaultPlan] = None,
                        retry: Optional[RetryPolicy] = None,
                        executor_factory: Optional[Callable] = None,
                        max_replans: int = 4,
                        ) -> Tuple[np.ndarray, ElasticReport]:
    """Execute a sharded plan one round at a time, surviving rank loss.

    Each round runs as a one-round continuation plan
    (:func:`replan_sharded` with the current round and mesh); between
    rounds the host array holds the complete committed state.  A
    :class:`~repro.core.faults.RankLossFault` injected mid-round (fault
    triggers address global ``(round, rank)`` sites) shrinks the mesh,
    re-plans the remaining rounds on the survivors, and redoes *only*
    the faulted round.  Any other terminal fault propagates as a
    :class:`~repro.core.recovery.PlanExecutionError` whose
    ``last_committed_round`` is the newest fully-stored round.

    ``executor_factory(mesh_shape)`` builds the per-mesh executor
    (default: a fresh zero-device
    :class:`~repro.core.executor.ShardedSimExecutor`); a factory
    returning :class:`~repro.core.executor.ShardMapExecutor` instances
    runs on real/fake devices — those dispatch one fused program, so
    injection is probed per rank before dispatch instead of per op."""
    from repro.core.executor import ShardedSimExecutor

    if executor_factory is None:
        def executor_factory(mesh_shape):
            return ShardedSimExecutor()

    injector = None
    if faults is not None:
        injector = faults if isinstance(faults, FaultInjector) \
            else faults.injector()

    fp = plan_fingerprint(plan)
    host = np.asarray(x)
    mesh = plan.mesh_shape
    rounds = plan.rounds
    mesh_history = [mesh]
    ex = executor_factory(mesh)
    rnd = replans = executed = 0
    while rnd < rounds:
        # one-round continuation plan on the current mesh
        step = replan_sharded(plan, plan.rounds - 1, mesh_shape=mesh)
        try:
            executed += 1
            if injector is None:
                host, _ = ex.execute(step, host)
            elif getattr(ex, "supports_injection", False):
                host, _ = ex.execute(
                    step, host, injector=injector.with_round_offset(rnd),
                    retry=retry)
            else:
                # fused-program backend: probe every rank's site before
                # dispatch (the program itself is all-or-nothing)
                view = injector.with_round_offset(rnd)
                for rank in range(step.n_ranks):
                    view.before_op(0, rank, "ShardKernel")
                host, _ = ex.execute(step, host)
            rnd += 1
        except (PlanExecutionError, InjectedFault) as e:
            f = e.fault if isinstance(e, PlanExecutionError) else e
            if not isinstance(f, RankLossFault) or replans >= max_replans:
                raise PlanExecutionError(
                    f"elastic sharded run failed at round {rnd}: {f}",
                    fault=f, last_committed_round=rnd - 1,
                    fingerprint=fp) from e
            # the surviving mesh takes over from the last stored round;
            # only the faulted round's transfers are repeated
            mesh = shrink_mesh(mesh, f.rank)
            mesh_history.append(mesh)
            replans += 1
            ex = executor_factory(mesh)
    return host, ElasticReport(
        rounds_total=rounds, rounds_executed=executed, replans=replans,
        mesh_history=tuple(mesh_history),
        faults_injected=injector.faults_injected if injector else 0,
        fingerprint=fp)
