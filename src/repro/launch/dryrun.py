import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first two lines above: jax locks the device count on first
init, and the dry-run (and only the dry-run) needs 512 placeholder
devices for the production meshes.

For each cell we build the real jitted program (full train_step with
optimizer update, or serve prefill/decode step), lower it against
ShapeDtypeStruct stand-ins (no allocation), compile, and record:

* ``memory_analysis()``  — per-device bytes (proves the cell fits),
* ``cost_analysis()``    — per-device HLO FLOPs + bytes accessed,
* collective bytes per op kind parsed from the compiled HLO,
* analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) for the
  useful-compute ratio.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --stencil          # L2 stencil cells

Artifacts: one JSON per cell under --out (default artifacts/dryrun/).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_NAMES, SHAPES, cell_supported, get_config, input_specs,
)
from repro.models.api import build_model
from repro.optim import AdamW
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .sharding import batch_specs, cache_specs, named, opt_specs, param_specs

MXU_PEAK = 197e12         # bf16 FLOP/s per chip (assignment constant)
VPU_PEAK = 3.9e12         # fp32 vector FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def _mem_stats(compiled):
    ma = compiled.memory_analysis()
    return {
        k: int(getattr(ma, k))
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }


def _cost_stats(compiled):
    ca = compiled.cost_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }


def _roofline(cost, colls, n_chips, seq_tokens, model_flops):
    """Three roofline terms (seconds, per step) + dominant bottleneck."""
    t_compute = cost["flops"] / MXU_PEAK           # per-device flops already
    t_memory = cost["bytes_accessed"] / HBM_BW
    wire = sum(
        colls[k] * f for k, f in
        (("all-gather", 1.0), ("all-reduce", 2.0), ("reduce-scatter", 1.0),
         ("all-to-all", 1.0), ("collective-permute", 1.0))
    )
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    useful = model_flops / n_chips
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dom,
        "model_flops_per_chip": useful,
        "useful_ratio": (useful / cost["flops"]) if cost["flops"] else 0.0,
        "roofline_fraction": (useful / MXU_PEAK) / max(
            max(terms.values()), 1e-30
        ),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               constrain_acts: bool = True, attn_seq_shard: bool = False,
               seq_shard_acts: bool = False, moe_block_dispatch: bool = False,
               moe_shard_map: bool = False, microbatches: int = 1):
    """Build, lower and compile one (arch x shape x mesh) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: model.init_params(key))
    pspecs = param_specs(cfg, params_shape, mesh)
    specs_in = input_specs(cfg, shape)

    # anchor (B, S, D) activations: batch over the data axes (pure GSPMD
    # propagation replicates batch — measured as "iter0" in §Perf)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.layers import (
        set_activation_sharding, set_attention_sharding,
    )
    from .mesh import data_axes
    dp = data_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if constrain_acts and shape.global_batch % n_dp == 0:
        if seq_shard_acts:
            # Megatron-SP style: residual stream sequence-sharded over
            # "model" between blocks (norms/projections are per-token)
            set_activation_sharding(NamedSharding(mesh, P(dp, "model", None)))
        else:
            set_activation_sharding(NamedSharding(mesh, P(dp, None, None)))
    else:
        set_activation_sharding(None)
    if attn_seq_shard and shape.global_batch % n_dp == 0:
        # §Perf: q-chunk axis of chunked attention sharded over "model"
        nq = mesh.shape["model"]
        set_attention_sharding(
            NamedSharding(mesh, P("model", dp, None, None, None, None)), nq
        )
    else:
        set_attention_sharding(None, None)
    from repro.models.moe import set_moe_block_dispatch, set_moe_shard_map
    if moe_shard_map and shape.global_batch % n_dp == 0:
        set_moe_shard_map(mesh, dp if len(dp) > 1 else dp[0])
    else:
        set_moe_shard_map(None, None)
    if moe_block_dispatch and shape.global_batch % n_dp == 0:
        # §Perf: per-data-shard MoE dispatch (shard-local capacity).
        # (gather-at-use weight constraints were tried and REFUTED —
        # EXPERIMENTS.md §Perf mixtral iter2; F-dim FSDP+TP in
        # launch/sharding.py is the fix that survived.)
        set_moe_block_dispatch(
            n_dp, NamedSharding(mesh, P(dp, None, None))
        )
    else:
        set_moe_block_dispatch(None, None)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = AdamW(moment_dtype=jnp.bfloat16)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = opt_specs(pspecs)
            bspecs = batch_specs(cfg, shape, specs_in, mesh)

            def step(params, opt_state, batch):
                if microbatches > 1:
                    # grad accumulation: live activations shrink ~1/mb
                    def split(x):
                        b = x.shape[0]
                        return x.reshape(microbatches, b // microbatches,
                                         *x.shape[1:])

                    micro = jax.tree.map(split, batch)

                    def acc(carry, mb):
                        g_acc, l_acc = carry
                        l, g = jax.value_and_grad(model.loss)(params, mb)
                        return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    (g, l), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
                    g = jax.tree.map(lambda x: x / microbatches, g)
                    loss = l / microbatches
                else:
                    loss, g = jax.value_and_grad(model.loss)(params, batch)
                params, opt_state = opt.update(g, opt_state, params)
                return params, opt_state, loss

            fn = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                              named(mesh, bspecs)),
                out_shardings=(named(mesh, pspecs), named(mesh, ospecs), None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_shape, opt_shape, specs_in)
            step_tokens = shape.global_batch * list(specs_in.values())[0].shape[1]
            flops_mult = 3  # fwd + bwd ~= 3x forward matmul flops
        elif shape.kind == "prefill":
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = cache_specs(cfg, shape, cache_shape, mesh)
            bspecs = batch_specs(cfg, shape, specs_in, mesh)

            def prefill(params, batch, cache):
                return model.prefill(params, batch, cache)

            fn = jax.jit(
                prefill,
                in_shardings=(named(mesh, pspecs), named(mesh, bspecs),
                              named(mesh, cspecs)),
                out_shardings=(None, named(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = fn.lower(params_shape, specs_in, cache_shape)
            step_tokens = shape.global_batch * specs_in["tokens"].shape[1]
            flops_mult = 1
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = cache_specs(cfg, shape, cache_shape, mesh)
            tok = specs_in["token"]
            tspec = batch_specs(cfg, shape, {"token": tok}, mesh)["token"]

            def decode(params, token, pos, cache):
                return model.decode_step(params, token, pos, cache)

            fn = jax.jit(
                decode,
                in_shardings=(named(mesh, pspecs), named(mesh, tspec), None,
                              named(mesh, cspecs)),
                out_shardings=(None, named(mesh, cspecs)),
                donate_argnums=(3,),
            )
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(params_shape, tok, pos, cache_shape)
            step_tokens = shape.global_batch  # one token per sequence
            flops_mult = 1

        compiled = lowered.compile()
    set_activation_sharding(None)
    set_attention_sharding(None, None)
    set_moe_block_dispatch(None, None)
    set_moe_shard_map(None, None)

    n_chips = mesh.devices.size
    hlo_text = compiled.as_text()
    hc = analyze_hlo(hlo_text)  # trip-count-aware (see hlo_analysis.py)
    cost = {"flops": hc.flops, "bytes_accessed": hc.bytes}
    colls = {k: int(v) for k, v in hc.collectives.items()}
    raw = _cost_stats(compiled)       # XLA's own numbers, for reference
    mem = _mem_stats(compiled)
    model_flops = flops_mult * 2 * cfg.active_param_count() * step_tokens
    roof = _roofline(cost, colls, n_chips, step_tokens, model_flops)
    return {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "skipped": False, "n_chips": n_chips,
        "compile_s": round(time.time() - t0, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "step_tokens": step_tokens,
        "memory": mem, "cost": cost, "cost_xla_raw": raw,
        "collectives": colls,
        "roofline": roof,
    }


def lower_stencil(multi_pod: bool, name: str = "box2d1r", k_ici: int = 8,
                  Y: int = 65536, X: int = 32768):
    """Dry-run the L2 distributed stencil on the production mesh."""
    from repro.core.distributed import distributed_stencil_step_fn

    mesh = make_production_mesh(multi_pod=multi_pod)
    row = "data"
    col = "model"
    # fold the pod axis into rows by treating ("pod","data") as rows
    if multi_pod:
        Yl = Y * 2
    else:
        Yl = Y
    fn = distributed_stencil_step_fn(name, k_ici, k_ici, mesh, row, col)
    x = jax.ShapeDtypeStruct((Yl, X), jnp.float32)
    t0 = time.time()
    with mesh:
        lowered = fn.lower(x)
        compiled = lowered.compile()
    hc = analyze_hlo(compiled.as_text())
    cost = {"flops": hc.flops, "bytes_accessed": hc.bytes}
    colls = {k: int(v) for k, v in hc.collectives.items()}
    mem = _mem_stats(compiled)
    t_comp = cost["flops"] / VPU_PEAK  # stencils are VPU work
    t_mem = cost["bytes_accessed"] / HBM_BW
    t_coll = colls["collective-permute"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    return {
        "arch": f"stencil-{name}-k{k_ici}", "shape": f"{Yl}x{X}",
        "multi_pod": multi_pod, "skipped": False,
        "n_chips": mesh.devices.size,
        "compile_s": round(time.time() - t0, 1),
        "memory": mem, "cost": cost, "collectives": colls,
        "roofline": {
            **{f"t_{k}": v for k, v in terms.items()},
            "dominant": max(terms, key=terms.get),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--stencil", action="store_true")
    ap.add_argument("--k-ici", type=int, default=8)
    ap.add_argument("--no-act-constraint", action="store_true",
                    help="pure-propagation baseline (perf iter0)")
    ap.add_argument("--attn-seq-shard", action="store_true",
                    help="sequence-sharded attention (perf iteration)")
    ap.add_argument("--seq-shard-acts", action="store_true",
                    help="sequence-sharded residual stream (Megatron-SP)")
    ap.add_argument("--moe-block-dispatch", action="store_true",
                    help="per-data-shard MoE dispatch (perf iteration)")
    ap.add_argument("--moe-shard-map", action="store_true",
                    help="explicit-collective shard_map MoE (perf iteration)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="grad-accumulation microbatches for train cells")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    jobs = []
    if args.stencil:
        for mp in meshes:
            jobs.append(("stencil", None, mp))
    else:
        archs = [args.arch] if args.arch else list(ARCH_NAMES)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                for mp in meshes:
                    jobs.append((a, s, mp))

    failures = 0
    for a, s, mp in jobs:
        tag = f"{a}__{s}__{'pod2' if mp else 'pod1'}"
        try:
            if a == "stencil":
                rec = lower_stencil(mp, k_ici=args.k_ici)
                tag = f"{rec['arch']}__{'pod2' if mp else 'pod1'}"
            else:
                rec = lower_cell(a, s, mp,
                                 constrain_acts=not args.no_act_constraint,
                                 attn_seq_shard=args.attn_seq_shard,
                                 seq_shard_acts=args.seq_shard_acts,
                                 moe_block_dispatch=args.moe_block_dispatch,
                                 moe_shard_map=args.moe_shard_map,
                                 microbatches=args.microbatches)
            status = "SKIP" if rec.get("skipped") else "OK"
            extra = rec.get("reason", "") if rec.get("skipped") else (
                f"compile={rec['compile_s']}s dom={rec['roofline']['dominant']}"
            )
            print(f"{status:4s} {tag}  {extra}", flush=True)
        except Exception as e:  # a failure here is a bug in the system
            failures += 1
            rec = {"arch": a, "shape": s, "multi_pod": mp, "error": str(e),
                   "traceback": traceback.format_exc()}
            print(f"FAIL {tag}  {e}", flush=True)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    print(f"done: {len(jobs) - failures}/{len(jobs)} cells OK")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
