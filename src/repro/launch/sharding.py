"""Per-architecture parameter / optimizer / batch / cache sharding rules.

Strategy (GSPMD, pjit): FSDP (weights sharded over the data axes, ZeRO-3)
x TP (d_ff / head / vocab dims over "model") x EP (experts over "model"
when E >= |model|).  Optimizer moments mirror parameter specs.  KV caches
shard batch over data and kv-heads over "model" — with divisibility-aware
fallbacks (cache length = split-KV decode, then head_dim) because jax
requires dims to divide evenly by their shard count; ragged vocabularies
(50280, 51865, ...) fall back from vocab- to d_model-sharding the same
way.

The rules are path-keyed (leaf name + rank) so one function covers all six
model families without coupling model code to meshes.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from .mesh import data_axes

__all__ = [
    "param_specs", "batch_specs", "cache_specs", "named", "opt_specs",
]


def _key_name(k) -> str:
    return getattr(k, "key", getattr(k, "name", str(k)))


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _assign(mesh, shape: Sequence[int],
            wants: List[Tuple[int, Any]]) -> P:
    """Build a PartitionSpec assigning each (dim, axis) in priority order,
    skipping assignments whose dim doesn't divide or whose axis/dim is
    already taken."""
    spec: List[Any] = [None] * len(shape)
    used = set()
    for dim, axis in wants:
        if dim < 0:
            dim += len(shape)
        if dim >= len(shape) or spec[dim] is not None:
            continue
        key = tuple(axis) if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in key):
            continue
        if shape[dim] % _axis_size(mesh, axis) != 0 or shape[dim] == 0:
            continue
        spec[dim] = axis
        used.update(key)
    return P(*spec)


def param_specs(cfg: ArchConfig, params_shape, mesh) -> Any:
    dp = data_axes(mesh)
    fsdp = dp[-1]  # shard weights over "data" (pod axis pure DP for weights)
    M = "model"
    ep = cfg.n_experts >= mesh.shape[M]

    def rule(path, leaf):
        names = [_key_name(k) for k in path]
        name = names[-1]
        shape = leaf.shape
        r = len(shape)
        if name == "embed":                       # (V, D)
            return _assign(mesh, shape, [(0, M), (1, fsdp), (1, M)])
        if name == "head":                        # (D, V)
            return _assign(mesh, shape, [(1, M), (0, fsdp), (0, M)])
        if name == "router":                      # (..., D, E)
            return _assign(mesh, shape, [(r - 2, fsdp)])
        if name in ("w_gate", "w_up") and r >= 4 and "moe" in names:
            if ep:                                # (S, E, D, F)
                return _assign(mesh, shape, [(r - 3, M), (r - 2, fsdp)])
            return _assign(mesh, shape, [(r - 1, M), (r - 2, fsdp)])
        if name == "w_down" and r >= 4 and "moe" in names:
            if ep:                                # (S, E, F, D)
                return _assign(mesh, shape, [(r - 3, M), (r - 1, fsdp)])
            return _assign(mesh, shape, [(r - 2, M), (r - 1, fsdp)])
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj"):
            # (..., D, F): TP on the output dim, FSDP on the input dim
            return _assign(mesh, shape,
                           [(r - 1, M), (r - 2, fsdp), (r - 2, M)])
        if name in ("wo", "w_down", "out_proj"):
            return _assign(mesh, shape,
                           [(r - 2, M), (r - 1, fsdp), (r - 1, M)])
        if name in ("conv_w", "conv_b"):          # (..., w, Cdim)
            return _assign(mesh, shape, [(r - 1, M)])
        return P()  # norms, gates, dt_bias, A_log, D — replicated

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_specs(pspecs):
    """Optimizer state mirrors parameter sharding; step is replicated."""
    from repro.optim import OptState
    return OptState(step=P(), mu=pspecs, nu=pspecs)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, batch_shape, mesh):
    dp = data_axes(mesh)
    n_dp = _axis_size(mesh, tuple(dp))

    def rule(path, leaf):
        name = _key_name(path[-1])
        s = leaf.shape
        if not s or s[0] % n_dp:
            return P()
        if name in ("tokens", "labels", "token"):
            return P(dp, *([None] * (len(s) - 1)))
        if name in ("images", "frames"):
            return _assign(mesh, s, [(0, dp), (2, "model")])
        return P()

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, cache_shape, mesh):
    dp = data_axes(mesh)
    n_dp = _axis_size(mesh, tuple(dp))
    M = "model"

    def rule(path, leaf):
        names = [_key_name(k) for k in path]
        name = names[-1]
        s = leaf.shape
        r = len(s)
        if name in ("k", "v"):
            # (..., B, L, G, hd): batch over dp; model over kv-heads,
            # falling back to cache length (split-KV) then head_dim
            b_dim, l_dim, g_dim, h_dim = r - 4, r - 3, r - 2, r - 1
            wants = []
            if s[b_dim] % n_dp == 0 and s[b_dim] >= n_dp:
                wants.append((b_dim, dp))
            else:
                # batch too small (e.g. long_500k B=1): split cache length
                wants.append((l_dim, dp))
            wants += [(g_dim, M), (l_dim, M), (h_dim, M)]
            return _assign(mesh, s, wants)
        if name == "ssm":
            # (..., B, H, P, N)
            b_dim, h_dim, p_dim = r - 4, r - 3, r - 2
            wants = [(b_dim, dp)] if s[b_dim] % n_dp == 0 and s[b_dim] >= n_dp else []
            wants += [(h_dim, M), (p_dim, M)]
            return _assign(mesh, s, wants)
        if name == "conv":
            # (..., B, w, Cdim)
            b_dim, c_dim = r - 3, r - 1
            wants = [(b_dim, dp)] if s[b_dim] % n_dp == 0 and s[b_dim] >= n_dp else []
            wants += [(c_dim, M)]
            return _assign(mesh, s, wants)
        return P()  # len counters

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
