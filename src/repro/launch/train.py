"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 200 --batch 8 --seq 256

On this CPU container use --smoke (reduced config, 1 device).  On a real
pod, drop --smoke: the full config is sharded over the production mesh
with the same code path (pjit + param_specs + activation constraints).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import DataSpec, SyntheticLM
from repro.models.api import build_model
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer


def add_modality_stub(batch, cfg, rng_seed=0):
    import numpy as np
    rng = np.random.default_rng(rng_seed)
    B = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_image_tokens, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frames, cfg.d_model)), jnp.bfloat16
        )
    return batch


class StubData:
    """Wraps SyntheticLM adding the per-family modality stubs."""

    def __init__(self, inner: SyntheticLM, cfg):
        self.inner = inner
        self.cfg = cfg

    def batch(self, step: int):
        return add_modality_stub(self.inner.batch(step), self.cfg, step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "bf16", "int8"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    data = StubData(
        SyntheticLM(DataSpec(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch)),
        cfg,
    )
    opt = AdamW(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                total_steps=args.steps)
    tc = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, grad_compression=args.grad_compression,
    )
    trainer = Trainer(model, opt, tc)
    params, opt_state, losses = trainer.run(
        jax.random.PRNGKey(0), data, resume=args.resume
    )
    n = max(len(losses) // 10, 1)
    print(f"first-10-mean {sum(losses[:n]) / n:.4f}  "
          f"last-10-mean {sum(losses[-n:]) / n:.4f}")
    if trainer.straggler_events:
        print(f"straggler events: {len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
