"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while/scan body ONCE, ignoring
trip counts (verified experimentally — a 10-iteration scan reports 10x
fewer flops than its unrolled twin).  Every model in this repo scans over
layers, so flops/bytes/collectives would be undercounted by 24-100x.

This module re-derives the three roofline inputs by walking the compiled
HLO text with loop multipliers:

* **flops** — ``dot``/``dot_general``: 2 x numel(result) x contraction
  size; elementwise arithmetic inside fusion bodies: numel(result) each.
* **bytes** — post-fusion HBM traffic model: every *materialized* compute
  instruction (fusion results, dots, reduces, copies/transposes, ...)
  counts 2 x result bytes (one write + ~one downstream read); bytes inside
  fusion bodies are register traffic and count nothing;
  ``dynamic-update-slice`` counts 2 x its *update* operand (it writes a
  slice, not its aliased full buffer); ``dynamic-slice`` counts 2 x its
  (slice-sized) result.  This avoids the pathological overcount of
  charging a full stacked (L, ...) tensor to every loop iteration that
  slices one layer out of it.
* **collectives** — result bytes per op kind (all-gather, all-reduce,
  reduce-scatter, all-to-all, collective-permute), multiplied through
  enclosing loops (also added to bytes once).

Trip counts come from the loop condition's ``constant(N)`` compare.
All numbers are per-device (the HLO module is the per-device SPMD
program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_ELEMENTWISE_FLOP_OPS = (
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "compare",
    "select", "floor", "ceil", "round-nearest-afz", "sign", "remainder",
)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLLECTIVES}
    )

    def __iadd__(self, other: "HloCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.collectives:
            self.collectives[k] += other.collectives[k]
        return self

    def scaled(self, m: float) -> "HloCost":
        return HloCost(
            flops=self.flops * m,
            bytes=self.bytes * m,
            collectives={k: v * m for k, v in self.collectives.items()},
        )


def _type_numel_bytes(type_str: str) -> Tuple[int, int]:
    """Total elements and bytes across all arrays in a (possibly tuple) type."""
    n_el, n_by = 0, 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_el += n
        n_by += n * _DTYPE_BYTES[dt]
    return n_el, n_by


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    body: List[str] = []
    for line in text.splitlines():
        s = line.rstrip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*{", s)
            if m:
                cur = m.group(1)
                body = []
                if s.strip().endswith("}"):  # single-line computation
                    comps[cur] = []
                    cur = None
        else:
            if s.strip() == "}":
                comps[cur] = body
                cur = None
            else:
                body.append(s)
    return comps


def _entry_name(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%([\w.\-]+)\s*\(", text, re.M)
    return m.group(1) if m else None


def _first_type(rhs: str) -> str:
    """The result type prefix of an instruction RHS (up to the op name)."""
    # rhs looks like: "f32[16,16]{1,0} dot(%a, %b), ..." or
    # "(s32[], f32[2,2]{1,0}) tuple(...)"
    depth = 0
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == " " and depth == 0:
            return rhs[:i]
    return rhs


def _op_name(rhs: str, type_str: str) -> str:
    rest = rhs[len(type_str):].strip()
    m = re.match(r"([\w\-]+)", rest)
    return m.group(1) if m else ""


def _operands(rhs: str, op: str, type_str: str) -> List[str]:
    rest = rhs[len(type_str):].strip()
    i = rest.find("(")
    if i < 0:
        return []
    depth = 0
    out, cur = [], []
    for ch in rest[i:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            if ch == "," and depth == 1:
                out.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    # operands may be bare ("%name") or typed ("f32[8,8]{1,0} %name")
    # depending on the XLA version's dump format — take the %name token
    names: List[str] = []
    for o in out:
        m = re.search(r"%([\w.\-]+)", o)
        if m:
            names.append(m.group(1))
    return names


def _trip_count(cond_body: List[str]) -> int:
    best = 1
    for line in cond_body:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    entry = _entry_name(text)
    if entry is None or entry not in comps:
        # fall back: the last computation is usually the entry
        entry = list(comps)[-1]

    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(name: str, fused: bool) -> HloCost:
        """Cost of one computation.  ``fused=True``: this body is inlined
        into a fusion — its intermediates are registers, so no bytes."""
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        total = HloCost()
        body = comps.get(name, [])
        shapes: Dict[str, str] = {}
        for line in body:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, rhs = m.group(1), m.group(2)
            t = _first_type(rhs)
            shapes[iname] = t
            op = _op_name(rhs, t)
            numel, nbytes = _type_numel_bytes(t)

            if op in ("dot", "dot_general"):
                # contraction size from lhs operand shape + contracting dims
                ops_ = _operands(rhs, op, t)
                k = 1
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if mm and ops_:
                    lhs_t = shapes.get(ops_[0], "")
                    sm = _SHAPE_RE.search(lhs_t)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for ci in mm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                total.flops += 2.0 * numel * k
                if not fused:
                    op_bytes = sum(
                        _type_numel_bytes(shapes.get(o, ""))[1] for o in ops_
                    )
                    total.bytes += nbytes + op_bytes
            elif op == "fusion":
                cm = re.search(r"calls=%([\w.\-]+)", rhs)
                if cm:
                    total += comp_cost(cm.group(1), True)
                if not fused:
                    total.bytes += 2.0 * nbytes
            elif op == "while":
                cond = re.search(r"condition=%([\w.\-]+)", rhs)
                bod = re.search(r"body=%([\w.\-]+)", rhs)
                if bod:
                    trips = _trip_count(comps.get(cond.group(1), [])) if cond else 1
                    total += comp_cost(bod.group(1), fused).scaled(trips)
            elif op in ("call", "conditional", "async-start"):
                for cm in re.finditer(r"(?:calls|to_apply|body)=%([\w.\-]+)", rhs):
                    total += comp_cost(cm.group(1), fused)
            elif any(op.startswith(c) for c in _COLLECTIVES):
                for c in _COLLECTIVES:
                    if op.startswith(c):
                        if op.endswith("-done"):
                            break  # counted at -start
                        total.collectives[c] += nbytes
                        total.bytes += nbytes
                        break
            elif op in _ELEMENTWISE_FLOP_OPS:
                total.flops += numel
                # no bytes: standalone elementwise is rare; fused is free
            elif op in ("reduce", "reduce-window"):
                ops_ = _operands(rhs, op, t)
                in_el = sum(
                    _type_numel_bytes(shapes.get(o, ""))[0] for o in ops_[:1]
                )
                total.flops += max(in_el, numel)
                if not fused:
                    total.bytes += 2.0 * nbytes
            elif op in ("convolution",):
                ops_ = _operands(rhs, op, t)
                kern = _type_numel_bytes(shapes.get(ops_[1], ""))[0] if len(ops_) > 1 else 1
                total.flops += 2.0 * numel * max(kern, 1) ** 0.5
                if not fused:
                    total.bytes += 2.0 * nbytes
            elif op == "dynamic-update-slice":
                # writes the update slice, not its aliased full buffer
                ops_ = _operands(rhs, op, t)
                upd = _type_numel_bytes(shapes.get(ops_[1], ""))[1] if len(ops_) > 1 else 0
                if not fused:
                    total.bytes += 2.0 * upd
            elif op in ("copy", "transpose", "reshape", "broadcast",
                        "concatenate", "slice", "dynamic-slice", "pad",
                        "gather", "scatter", "convert", "sort"):
                if not fused:
                    total.bytes += 2.0 * nbytes
        memo[key] = total
        return total

    return comp_cost(entry, False)
