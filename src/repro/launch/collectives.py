"""HLO-text collective accounting for the roofline's third term.

``cost_analysis`` does not expose collective bytes, so we parse the
compiled HLO module: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction's
*result* size is summed per op kind.  (Result size is the standard proxy:
for all-gather it's the gathered bytes each device receives; for
all-reduce the reduced tensor crosses links ~2x in a ring — the roofline
multiplies by the per-op ring factor.)
"""
from __future__ import annotations

import re
from typing import Dict

__all__ = ["collective_bytes", "RING_FACTORS"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8": 1, "s8": 1, "u8": 1, "pred": 1,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")

# bytes-on-wire multiplier for ring algorithms, relative to result bytes
RING_FACTORS = {
    "all-gather": 1.0,        # each device receives ~result bytes
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_ARRAY_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _array_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective in an HLO module dump."""
    out: Dict[str, int] = {op: 0 for op in _OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _OPS:
            # "%x = TYPE op-name(" — the `op-name(` or `op-name-start(` form
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                lhs = stripped.split(f" {op}", 1)[0]
                if "=" not in lhs:
                    continue
                rtype = lhs.split("=", 1)[1]
                out[op] += sum(_array_bytes(m) for m in _ARRAY_RE.finditer(rtype))
                break
    return out
