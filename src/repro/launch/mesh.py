"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

from ..compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "data_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
