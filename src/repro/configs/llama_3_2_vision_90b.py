"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; 100 layers =
20 groups of (4 self-attn + 1 gated cross-attn to stub patch embeddings).
The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, 1601, d_model).
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab=128256, cross_attn_every=5, n_image_tokens=1601,
    rope_theta=5e5,
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=10, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, cross_attn_every=5, n_image_tokens=17,
)
