"""mixtral-8x7b — 8 experts top-2, SWA [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, moe_every=1, sliding_window=4096,
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=256,
    n_experts=4, top_k=2, moe_every=1, sliding_window=32, capacity_factor=4.0,
)
