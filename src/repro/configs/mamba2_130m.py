"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, d_head=64,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-130m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_head=16,
    d_ff=0, vocab=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    tie_embeddings=True,
)
