"""zamba2-2.7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32, MHA in the shared block) d_ff=10240
vocab=32000, ssm_state=64.  54 layers = 9 groups of (5 Mamba2 + 1
application of the ONE shared-weight attention block).
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, attn_every=3,
    ssm_chunk=16, tie_embeddings=True,
)
