"""Architecture config registry + dry-run input specs.

``get_config(name)`` / ``get_smoke_config(name)`` resolve the 10 assigned
architectures; ``input_specs(cfg, shape)`` builds the ShapeDtypeStruct
stand-ins the multi-pod dry-run lowers against (no device allocation).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import ArchConfig, ShapeSpec, SHAPES  # noqa: F401

from . import (
    minitron_4b, phi3_medium_14b, h2o_danube_1_8b, qwen3_0_6b,
    llama_3_2_vision_90b, zamba2_2_7b, llama4_maverick_400b, mixtral_8x7b,
    whisper_tiny, mamba2_130m,
)

_MODULES = {
    "minitron-4b": minitron_4b,
    "phi3-medium-14b": phi3_medium_14b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "qwen3-0.6b": qwen3_0_6b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
    "zamba2-2.7b": zamba2_2_7b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b,
    "mixtral-8x7b": mixtral_8x7b,
    "whisper-tiny": whisper_tiny,
    "mamba2-130m": mamba2_130m,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    try:
        return _MODULES[name].FULL
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")


def get_smoke_config(name: str) -> ArchConfig:
    return _MODULES[name].SMOKE


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable?  Returns (ok, reason-if-not).

    Assignment rules: ``long_500k`` needs sub-quadratic attention — skipped
    for pure full-attention archs; whisper's enc-dec lengths are bounded
    far below 500k.
    """
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False, "enc-dec: source/target lengths << 500k"
        if not cfg.sub_quadratic:
            return False, "pure full-attention arch: O(S) KV decode at 500k infeasible"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {
            "tokens": sd((B, S), i32),
            "labels": sd((B, S), i32),
        }
        if cfg.family == "vlm":
            specs["images"] = sd((B, cfg.n_image_tokens, cfg.d_model), bf16)
        if cfg.family == "encdec":
            specs["frames"] = sd((B, cfg.n_frames, cfg.d_model), bf16)
            # decoder trains on bounded target lengths
            specs["tokens"] = sd((B, min(S, cfg.max_target_len)), i32)
            specs["labels"] = sd((B, min(S, cfg.max_target_len)), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sd((B, S), i32)}
        if cfg.family == "vlm":
            specs["images"] = sd((B, cfg.n_image_tokens, cfg.d_model), bf16)
        if cfg.family == "encdec":
            specs["frames"] = sd((B, cfg.n_frames, cfg.d_model), bf16)
            specs["tokens"] = sd((B, min(S, cfg.max_target_len)), i32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"token": sd((B, 1), i32)}
