"""llama4-maverick-400b-a17b — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 on alternating layers (24 dense + 24 MoE ≈ 397 B params, matching
the 400b-a17b name; all-MoE at these dims would be ~790 B — see DESIGN.md).
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, moe_every=2,
)

SMOKE = ArchConfig(
    name="llama4-maverick-400b-a17b-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=256,
    n_experts=8, top_k=1, moe_every=2, capacity_factor=4.0,
)
