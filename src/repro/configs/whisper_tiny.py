"""whisper-tiny — enc-dec, conv frontend (stub) [arXiv:2212.04356].

4L encoder + 4L decoder, d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The conv/mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, 1500, d_model).
"""
from .base import ArchConfig

FULL = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_head=64, d_ff=1536, vocab=51865,
    norm="layernorm", mlp="gelu", n_frames=1500, max_target_len=448,
)

SMOKE = ArchConfig(
    name="whisper-tiny-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256,
    norm="layernorm", mlp="gelu", n_frames=24, max_target_len=32,
)
