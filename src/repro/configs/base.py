"""Architecture config schema + shape presets (assignment spec)."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0          # 0 -> d_model // n_heads
    # attention options
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    norm: str = "rmsnorm"    # rmsnorm | layernorm
    mlp: str = "swiglu"      # swiglu | gelu
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1       # MoE on layers with (layer % moe_every == moe_every - 1)
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    # hybrid (zamba2): one *shared* attention block applied every attn_every
    attn_every: int = 0
    # vlm: cross-attn image layers every cross_attn_every (within a group)
    cross_attn_every: int = 0
    n_image_tokens: int = 1601
    # encdec (whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500
    max_target_len: int = 448

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports O(1)-state or bounded-window 500k-token decode."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.d_head
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        ffn_dense = 3 * d * f if self.mlp == "swiglu" else 2 * d * f
        n = 0
        if self.family == "ssm":
            dm = self.d_inner
            per = d * (2 * dm + 2 * self.ssm_state + self.ssm_heads) + dm * d
            n += self.n_layers * per
        elif self.family == "hybrid":
            dm = self.d_inner
            per = d * (2 * dm + 2 * self.ssm_state + self.ssm_heads) + dm * d
            n_mamba = self.n_layers - self.n_layers // self.attn_every
            n += n_mamba * per
            n += attn + ffn_dense  # ONE shared attention block
        elif self.family == "moe":
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            n += self.n_layers * attn
            n += n_dense * ffn_dense
            n += n_moe * (self.n_experts * ffn_dense + d * self.n_experts)
        elif self.family == "vlm":
            n += self.n_layers * (attn + ffn_dense)
            # cross layers replace self-attn with cross-attn (same shape)
        elif self.family == "encdec":
            n += self.n_enc_layers * (attn + ffn_dense)
            n += self.n_layers * (2 * attn + ffn_dense)  # self + cross
        else:
            n += self.n_layers * (attn + ffn_dense)
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only) — for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.d_head
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        ffn = 3 * d * f
        n_moe = self.n_layers // self.moe_every
        n_dense = self.n_layers - n_moe
        n = self.n_layers * attn + n_dense * ffn
        n += n_moe * (self.top_k * ffn + d * self.n_experts)
        n += v * d * (1 if self.tie_embeddings else 2)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
