"""repro: SO2DR on TPU — see README.md / DESIGN.md.

Stable top-level API.  Everything a typical user touches is importable
from ``repro`` directly and listed in ``__all__``:

* ``Box`` — the N-D coordinate type every plan op is expressed in;
* ``compile_plan`` / ``compile_plan_nd`` / ``compile_box_plan`` —
  engine-to-plan entry points (2-D rows, N-D chunking, BoxTB temporal
  blocking);
* ``get_engine`` / ``get_executor`` — the planner and interpreter
  registries;
* ``tune`` / ``TuneSpec`` / ``TuneResult`` — the one tuner entry point
  (row, box and sharded sweeps behind a single spec), with optional
  measured refinement of the dry-run top-k;
* ``DeviceProfile`` / ``calibrate`` — measured-cost calibration: fitted
  per-device model constants, loadable as a ``Hardware`` drop-in;
* ``autotune`` / ``autotune_box`` / ``autotune_sharded`` — deprecated
  aliases of the per-mode sweeps (use ``tune``);
* ``compile_hierarchical`` / ``HierarchicalPlan`` — nested out-of-core
  streaming inside shards when a subdomain exceeds device capacity;
* ``compress_plan`` / ``get_codec`` — the transfer-codec rewrite pass
  (H2D/D2H transfers *and* sharded halo exchanges);
* ``StencilService`` / ``StencilJob`` — the persistent plan server;
* ``FaultPlan`` / ``RetryPolicy`` / ``run_with_recovery`` /
  ``PlanCheckpointer`` — deterministic fault injection and
  checkpoint/resume execution (see README's fault-tolerance section).

Deeper machinery keeps its module-level home (``repro.core.lower``,
``repro.kernels.dispatch``, ``repro.core.distributed``, ...); those
paths are documented in README.md and are stable too, but they are not
re-exported here.
"""
from .core import (  # noqa: F401
    Box,
    ExecutionPlan,
    ShardedPlan,
    TransferStats,
    Stencil,
    get_stencil,
    Hardware,
    RTX3080_PAPER,
    TPU_V5E,
    compile_plan,
    compile_plan_nd,
    compile_box_plan,
    compile_sharded,
    compile_hierarchical,
    HierarchicalPlan,
    get_engine,
    get_executor,
    get_codec,
    compress_plan,
    autotune,
    autotune_box,
    autotune_sharded,
    tune,
    TuneSpec,
    TuneResult,
    DeviceProfile,
    calibrate,
    resolve_hardware,
    run_reference,
    FaultPlan,
    FaultTrigger,
    RetryPolicy,
    InjectedFault,
    PlanExecutionError,
    PlanCheckpointer,
    resume_plan,
    run_with_recovery,
)
from .serve import JobResult, StencilJob, StencilService  # noqa: F401

__all__ = [
    "Box",
    "ExecutionPlan",
    "ShardedPlan",
    "TransferStats",
    "Stencil",
    "get_stencil",
    "Hardware",
    "RTX3080_PAPER",
    "TPU_V5E",
    "compile_plan",
    "compile_plan_nd",
    "compile_box_plan",
    "compile_sharded",
    "compile_hierarchical",
    "HierarchicalPlan",
    "get_engine",
    "get_executor",
    "get_codec",
    "compress_plan",
    "autotune",
    "autotune_box",
    "autotune_sharded",
    "tune",
    "TuneSpec",
    "TuneResult",
    "DeviceProfile",
    "calibrate",
    "resolve_hardware",
    "run_reference",
    "FaultPlan",
    "FaultTrigger",
    "RetryPolicy",
    "InjectedFault",
    "PlanExecutionError",
    "PlanCheckpointer",
    "resume_plan",
    "run_with_recovery",
    "JobResult",
    "StencilJob",
    "StencilService",
]
