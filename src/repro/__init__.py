"""repro: SO2DR on TPU — see README.md / DESIGN.md."""
