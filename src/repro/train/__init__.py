from .loop import TrainConfig, Trainer, compress_grads  # noqa: F401
