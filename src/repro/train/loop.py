"""Training loop: microbatched grad accumulation, gradient compression
with error feedback, straggler watchdog, checkpoint/restart.

Distributed-optimization features (assignment: "tricks at 1000+ nodes"):

* **grad accumulation** — ``microbatches`` splits the per-host batch so
  arbitrarily large global batches fit; accumulation runs inside one jit
  (lax.scan over microbatches), letting XLA overlap the per-microbatch
  reduce-scatters with the next microbatch's backward.
* **gradient compression** — optional bf16 (or int8 w/ per-tensor scale)
  cast *before* the cross-replica reduction with error-feedback residuals,
  halving/quartering DP all-reduce bytes (Seide et al. / DGC lineage).
* **straggler watchdog** — per-step wall-time EWMA; steps slower than
  ``watchdog_factor``× the EWMA are logged as straggler events (on real
  multi-host deployments this hooks the coordinator's re-slice path).
* **checkpoint/restart** — atomic CheckpointManager; data pipeline is
  stateless-by-step so resume is bitwise-identical (tested).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.optim import AdamW

__all__ = ["TrainConfig", "Trainer", "compress_grads"]


def compress_grads(grads, residual, mode: str = "bf16"):
    """Lossy-compress gradients with error feedback.

    Returns (compressed-then-decompressed grads, new residual).  The
    quantize→dequantize round trip models what crosses the interconnect;
    error feedback keeps the *accumulated* quantization error bounded.
    """
    if mode == "none":
        return grads, residual

    def comp(g, r):
        g = g.astype(jnp.float32) + r
        if mode == "bf16":
            q = g.astype(jnp.bfloat16).astype(jnp.float32)
        elif mode == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.round(g / scale).clip(-127, 127) * scale
        else:
            raise ValueError(mode)
        return q, g - q

    out = jax.tree.map(comp, grads, residual)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return q, r


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    grad_compression: str = "none"   # none | bf16 | int8
    watchdog_factor: float = 3.0


class Trainer:
    def __init__(self, model, optimizer: AdamW, tc: TrainConfig,
                 donate: bool = True):
        self.model = model
        self.opt = optimizer
        self.tc = tc
        self.ckpt = CheckpointManager(tc.ckpt_dir, tc.ckpt_keep) if tc.ckpt_dir else None
        self.straggler_events: list = []
        self._step_fn = self._build_step(donate)

    def _build_step(self, donate: bool):
        model, opt, tc = self.model, self.opt, self.tc

        def loss_fn(params, batch):
            return model.loss(params, batch)

        def step(params, opt_state, residual, batch):
            if tc.microbatches > 1:
                def split(x):
                    b = x.shape[0]
                    return x.reshape(tc.microbatches, b // tc.microbatches, *x.shape[1:])

                micro = jax.tree.map(split, batch)

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (g, l), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
                g = jax.tree.map(lambda x: x / tc.microbatches, g)
                loss = l / tc.microbatches
            else:
                loss, g = jax.value_and_grad(loss_fn)(params, batch)

            g, residual = compress_grads(g, residual, tc.grad_compression)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, residual, loss

        kwargs = {"donate_argnums": (0, 1, 2)} if donate else {}
        return jax.jit(step, **kwargs)

    def init_state(self, rng):
        params = self.model.init_params(rng)
        opt_state = self.opt.init(params)
        residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ) if self.tc.grad_compression != "none" else jax.tree.map(
            lambda p: jnp.zeros((), jnp.float32), params
        )
        return params, opt_state, residual

    def run(self, rng, data, start_step: int = 0, resume: bool = False):
        params, opt_state, residual = self.init_state(rng)
        step0 = start_step
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            (params, opt_state, residual), meta = self.ckpt.restore(
                (params, opt_state, residual)
            )
            step0 = meta["step"] + 1

        losses = []
        ewma = None
        for step in range(step0, self.tc.steps):
            t0 = time.perf_counter()
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt_state, residual, loss = self._step_fn(
                params, opt_state, residual, batch
            )
            loss = float(loss)
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tc.watchdog_factor * ewma and step > step0 + 3:
                self.straggler_events.append((step, dt, ewma))
            losses.append(loss)
            if self.ckpt and (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step, (params, opt_state, residual))
            if (step + 1) % self.tc.log_every == 0:
                print(f"step {step + 1:5d}  loss {loss:.4f}  {dt * 1e3:.0f} ms")
        return params, opt_state, losses
