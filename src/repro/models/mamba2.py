"""Mamba-2 (SSD — state-space duality) blocks.

The SSD chunked scan is the direct structural analogue of the paper's
SO2DR (DESIGN.md §Arch-applicability): the sequence is split into chunks,
an O(N·P) carried state plays the role of the region-sharing buffer at
chunk boundaries, and the intra-chunk quadratic part is uninterrupted
on-chip work — temporal blocking along the sequence axis.

Shapes: x (B, S, H, P) heads×head_dim, B/C (B, S, N) state projections
(single group), dt (B, S, H), A (H,) negative decay.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["mamba_init", "mamba_apply", "mamba_init_state", "mamba_decode_step"]


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} a_k
    for i >= j, -inf otherwise (log-space decay matrix)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def mamba_init(key, cfg: ArchConfig):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(cfg.d_model),
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * di + 2 * N + H),
        "conv_w": jax.random.normal(ks[1], (w, conv_dim), jnp.float32) * (w ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "gn": rmsnorm_init(di),
        "out_proj": dense_init(ks[2], di, cfg.d_model),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xBC, dt


def _causal_conv(p, xBC: jnp.ndarray, w: int) -> jnp.ndarray:
    """Depthwise causal conv1d along S.  xBC: (B, S, C)."""
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1]] * p["conv_w"][i].astype(xBC.dtype)
        for i in range(w)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD chunked scan.

    x: (B,S,H,P) *already* dt-scaled inputs? No — raw; dt applied here.
    dt: (B,S,H) softplus'd;  A: (H,) negative;  Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    f32 = jnp.float32
    xdt = (x * dt[..., None]).astype(f32).reshape(Bsz, nc, Q, H, P)
    dA = (dt * A).astype(f32).reshape(Bsz, nc, Q, H)        # (B,nc,Q,H)
    dA = jnp.moveaxis(dA, 3, 2)                              # (B,nc,H,Q)
    Bc = Bm.astype(f32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, Q, N)

    L = jnp.exp(_segsum(dA))                                 # (B,nc,H,Q,Q)
    # intra-chunk (the "on-chip" quadratic part)
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", Cc, Bc, L, xdt)

    dA_cum = jnp.cumsum(dA, axis=-1)                         # (B,nc,H,Q)
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)        # (B,nc,H,Q)
    chunk_states = jnp.einsum("bckn,bchk,bckhp->bchpn", Bc, decay_states, xdt)

    # inter-chunk recurrence (the "region-sharing" state hand-off)
    chunk_decay = jnp.exp(dA_cum[..., -1])                   # (B,nc,H)

    def step(h, inp):
        s_c, g_c = inp
        h_new = h * g_c[..., None, None] + s_c
        return h_new, h  # emit state *entering* this chunk

    h0 = jnp.zeros((Bsz, H, P, N), f32) if init_state is None else init_state.astype(f32)
    hT, prev = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev = jnp.moveaxis(prev, 0, 1)                          # (B,nc,H,P,N)

    out_decay = jnp.exp(dA_cum)                              # (B,nc,H,Q)
    y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cc, prev, out_decay)

    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), hT


def mamba_apply(
    p,
    cfg: ArchConfig,
    u: jnp.ndarray,                       # (B, S, D)
    init_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    """Full-sequence Mamba-2 block (training / prefill)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, S, D = u.shape
    res = u
    x = rmsnorm(p["ln"], u)
    z, xBC_raw, dt = _split_proj(cfg, dense(p["in_proj"], x))
    xBC = _causal_conv(p, xBC_raw, cfg.conv_width)
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di : di + N]
    Cm = xBC[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, hT = _ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(p["gn"], y * jax.nn.silu(z))
    from .layers import constrain_acts

    out = constrain_acts(res + dense(p["out_proj"], y))
    if return_state:
        # conv history for decode continuity: last (w-1) raw conv inputs
        w = cfg.conv_width
        tail = xBC_raw[:, -(w - 1):].astype(jnp.bfloat16)
        pad = (w - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"ssm": hT, "conv": tail}
    return out, None


def mamba_init_state(cfg: ArchConfig, batch: int):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.bfloat16),
    }


def mamba_decode_step(p, cfg: ArchConfig, u: jnp.ndarray, state):
    """One-token recurrent step.  u: (B, 1, D) -> (B, 1, D), new state."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B = u.shape[0]
    res = u
    x = rmsnorm(p["ln"], u)
    z, xBC, dt = _split_proj(cfg, dense(p["in_proj"], x))  # (B,1,*)
    # conv cache: last (w-1) inputs
    hist = jnp.concatenate([state["conv"], xBC.astype(state["conv"].dtype)], axis=1)  # (B,w,Cdim)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), p["conv_w"])
    xBC1 = jax.nn.silu(conv_out + p["conv_b"]).astype(u.dtype)[:, None]  # (B,1,C)
    new_conv = hist[:, 1:]

    xs = xBC1[..., :di].reshape(B, H, P)
    Bm = xBC1[..., di : di + N].reshape(B, N).astype(jnp.float32)
    Cm = xBC1[..., di + N :].reshape(B, N).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32).reshape(B, H) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtv * A)                                     # (B,H)
    xdt = (xs.astype(jnp.float32) * dtv[..., None])           # (B,H,P)
    h = state["ssm"] * dA[..., None, None] + xdt[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm).astype(u.dtype)
    y = y + xs * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(B, 1, di)
    y = rmsnorm(p["gn"], y * jax.nn.silu(z))
    out = res + dense(p["out_proj"], y)
    return out, {"ssm": h, "conv": new_conv}
