"""Unified model API: build(config) -> Model with init/forward/serve closures.

One entry point for all 10 assigned architectures:

* ``dense``   minitron-4b, phi3-medium-14b, h2o-danube-1.8b (SWA), qwen3-0.6b
* ``moe``     mixtral-8x7b (every layer), llama4-maverick (alternating)
* ``ssm``     mamba2-130m
* ``hybrid``  zamba2-2.7b (Mamba2 backbone + ONE shared attention block)
* ``vlm``     llama-3.2-vision-90b (groups of 4 self + 1 gated cross-attn)
* ``encdec``  whisper-tiny (bidirectional encoder + cross-attending decoder)

Every family exposes the same surface:
    init_params(key)                        -> params pytree
    forward(params, batch)                  -> (logits, aux_loss)
    loss(params, batch)                     -> scalar
    init_cache(batch_size, max_len)         -> cache pytree
    prefill(params, batch, cache)           -> (last logits, cache)
    decode_step(params, token, pos, cache)  -> (logits, cache)

Stacks scan over stacked layer params with per-layer remat; modality
frontends (vision patches, audio frames) are stubs per the assignment:
``batch["images"]`` / ``batch["frames"]`` carry precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense, dense_init, embed_init
from .transformer import (
    attn_apply, attn_init, block_apply, block_init, mlp_apply, mlp_init,
    norm_apply, norm_init, stack_init,
    dense_params_init, dense_forward, dense_init_cache, dense_prefill,
    dense_decode_step,
)
from .moe import moe_apply, moe_init
from .mamba2 import mamba_apply, mamba_decode_step, mamba_init, mamba_init_state

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable
    forward: Callable      # (params, batch) -> (logits, aux)
    init_cache: Callable   # (batch, max_len) -> cache
    prefill: Callable      # (params, batch, cache) -> (logits, cache)
    decode_step: Callable  # (params, token, pos, cache) -> (logits, cache)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        # one-hot contraction instead of take_along_axis: the gather would
        # force GSPMD to all-gather the vocab-sharded logits (127 GB/device
        # at qwen3 train_4k); the masked sum keeps the vocab dim sharded.
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        onehot = (labels[..., None] == vocab_iota)
        ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
        return jnp.mean(lse - ll) + 0.01 * aux


def _embed_tokens(p, tokens):
    from .layers import constrain_acts

    return constrain_acts(p["embed"][tokens].astype(jnp.bfloat16))


def _head(p, cfg, x):
    if cfg.tie_embeddings:
        return x @ p["embed"].T.astype(x.dtype)
    return x @ p["head"].astype(x.dtype)


def _sinusoid(S: int, D: int, dtype=jnp.bfloat16):
    pos = jnp.arange(S)[:, None]
    i = jnp.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * i / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _sinusoid_at(pos, D: int, dtype=jnp.bfloat16):
    i = jnp.arange(D // 2)
    ang = pos / (10000 ** (2 * i / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# =============================================================== dense family

def _build_dense(cfg: ArchConfig) -> Model:
    def forward(p, batch):
        return dense_forward(p, cfg, batch["tokens"]), 0.0

    def init_cache(batch, max_len):
        return dense_init_cache(cfg, batch, max_len)

    def prefill(p, batch, cache):
        return dense_prefill(p, cfg, batch["tokens"], cache)

    def decode_step(p, token, pos, cache):
        return dense_decode_step(p, cfg, token, pos, cache)

    return Model(cfg, functools.partial(dense_params_init, cfg=cfg),
                 forward, init_cache, prefill, decode_step)


# ================================================================= MoE family

def _moe_super_init(key, cfg: ArchConfig):
    """One super-block: (moe_every - 1) dense blocks + 1 MoE block."""
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "moe_ln1": norm_init(cfg),
        "moe_attn": attn_init(k1, cfg),
        "moe_ln2": norm_init(cfg),
        "moe": moe_init(k2, cfg),
    }
    if cfg.moe_every > 1:
        p["dense_blocks"] = stack_init(k3, cfg, cfg.moe_every - 1)
    return p


def _moe_super_apply(p, cfg: ArchConfig, x, positions, caches=None):
    """caches: dict with 'dense' (stacked) and 'moe' entries or None."""
    aux = 0.0
    new_caches = {}
    if cfg.moe_every > 1:
        def body(x, inp):
            bp, bc = inp
            y, c = block_apply(bp, cfg, x, positions=positions, cache=bc,
                               window=cfg.sliding_window)
            return y, c

        dc = caches["dense"] if caches is not None else None
        if dc is None:
            x, _ = jax.lax.scan(lambda x, bp: body(x, (bp, None)), x, p["dense_blocks"])
        else:
            x, ndc = jax.lax.scan(body, x, (p["dense_blocks"], dc))
            new_caches["dense"] = ndc
    h, nc = attn_apply(p["moe_attn"], cfg, norm_apply(cfg, p["moe_ln1"], x),
                       positions=positions,
                       cache=None if caches is None else caches["moe"],
                       window=cfg.sliding_window)
    x = x + h
    y, a = moe_apply(p["moe"], cfg, norm_apply(cfg, p["moe_ln2"], x))
    x = x + y
    aux = aux + a
    if caches is not None:
        new_caches["moe"] = nc
        return x, aux, new_caches
    return x, aux, None


def _build_moe(cfg: ArchConfig) -> Model:
    n_super = cfg.n_layers // cfg.moe_every

    def init_params(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "embed": embed_init(k1, cfg.vocab, cfg.d_model),
            "supers": stack_init(k2, cfg, n_super, init_fn=_moe_super_init),
            "ln_f": norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(k3, cfg.d_model, cfg.vocab, scale=0.02)
        return p

    def forward(p, batch):
        tokens = batch["tokens"]
        x = _embed_tokens(p, tokens)
        positions = jnp.arange(tokens.shape[1])

        @jax.checkpoint
        def body(carry, sp):
            x, aux = carry
            x, a, _ = _moe_super_apply(sp, cfg, x, positions)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, 0.0), p["supers"])
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), aux

    def init_cache(batch, max_len):
        L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        def kv(n):
            return {
                "k": jnp.zeros((n, batch, L, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
                "v": jnp.zeros((n, batch, L, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
                "len": jnp.zeros((n,), jnp.int32),
            }
        c: Dict[str, Any] = {"moe": kv(n_super)}
        if cfg.moe_every > 1:
            c["dense"] = jax.tree.map(
                lambda a: a.reshape((n_super, cfg.moe_every - 1) + a.shape[1:]),
                kv(n_super * (cfg.moe_every - 1)),
            )
        return c

    def _run_cached(p, x, positions, cache):
        def body(carry, inp):
            x, aux = carry
            sp, sc = inp
            x, a, nc = _moe_super_apply(sp, cfg, x, positions, caches=sc)
            return (x, aux + a), nc

        (x, aux), ncache = jax.lax.scan(body, (x, 0.0), (p["supers"], cache))
        x = norm_apply(cfg, p["ln_f"], x)
        return x, ncache

    def prefill(p, batch, cache):
        tokens = batch["tokens"]
        x = _embed_tokens(p, tokens)
        positions = jnp.arange(tokens.shape[1])
        x, ncache = _run_cached(p, x, positions, cache)
        return _head(p, cfg, x[:, -1:]), ncache

    def decode_step(p, token, pos, cache):
        x = _embed_tokens(p, token)
        positions = jnp.asarray([pos])
        x, ncache = _run_cached(p, x, positions, cache)
        return _head(p, cfg, x), ncache

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ================================================================= SSM family

def _build_ssm(cfg: ArchConfig) -> Model:
    def init_params(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "embed": embed_init(k1, cfg.vocab, cfg.d_model),
            "layers": stack_init(k2, cfg, cfg.n_layers, init_fn=mamba_init),
            "ln_f": norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(k3, cfg.d_model, cfg.vocab, scale=0.02)
        return p

    def forward(p, batch):
        x = _embed_tokens(p, batch["tokens"])

        @jax.checkpoint
        def body(x, lp):
            y, _ = mamba_apply(lp, cfg, x)
            return y, None

        x, _ = jax.lax.scan(body, x, p["layers"])
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), 0.0

    def init_cache(batch, max_len):
        one = mamba_init_state(cfg, batch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one
        )

    def prefill(p, batch, cache):
        x = _embed_tokens(p, batch["tokens"])

        def body(x, inp):
            lp, lc = inp
            return mamba_apply(lp, cfg, x, return_state=True)

        x, ncache = jax.lax.scan(body, x, (p["layers"], cache))
        x = norm_apply(cfg, p["ln_f"], x[:, -1:])
        return _head(p, cfg, x), ncache

    def decode_step(p, token, pos, cache):
        x = _embed_tokens(p, token)

        def body(x, inp):
            lp, lc = inp
            return mamba_decode_step(lp, cfg, x, lc)

        x, ncache = jax.lax.scan(body, x, (p["layers"], cache))
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), ncache

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ============================================================== hybrid family

def _build_hybrid(cfg: ArchConfig) -> Model:
    """zamba2: groups of (attn_every - 1) Mamba2 layers + ONE shared
    attention block (weights shared across all groups)."""
    per = cfg.attn_every - 1
    n_groups = cfg.n_layers // cfg.attn_every

    def init_params(key):
        ks = jax.random.split(key, 5)
        p = {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
            "mamba": jax.vmap(lambda k: stack_init(k, cfg, per, init_fn=mamba_init))(
                jax.random.split(ks[1], n_groups)
            ),
            "shared": block_init(ks[2], cfg),   # the ONE shared attn block
            "ln_f": norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(ks[3], cfg.d_model, cfg.vocab, scale=0.02)
        return p

    def _group(p_shared, gp, cfg, x, positions, gcache):
        """one group: per mamba layers + shared attn application."""
        new_cache = {}
        if gcache is None:
            def mbody(x, lp):
                y, _ = mamba_apply(lp, cfg, x)
                return y, None
            x, _ = jax.lax.scan(mbody, x, gp)
        else:
            def mbody(x, inp):
                lp, lc = inp
                if x.shape[1] == 1:
                    return mamba_decode_step(lp, cfg, x, lc)
                return mamba_apply(lp, cfg, x, return_state=True)
            x, mc = jax.lax.scan(mbody, x, (gp, gcache["mamba"]))
            new_cache["mamba"] = mc
        ac = None if gcache is None else gcache["attn"]
        x, nac = block_apply(p_shared, cfg, x, positions=positions, cache=ac)
        if gcache is not None:
            new_cache["attn"] = nac
            return x, new_cache
        return x, None

    def forward(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        positions = jnp.arange(x.shape[1])

        @jax.checkpoint
        def body(x, gp):
            y, _ = _group(p["shared"], gp, cfg, x, positions, None)
            return y, None

        x, _ = jax.lax.scan(body, x, p["mamba"])
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), 0.0

    def init_cache(batch, max_len):
        one = mamba_init_state(cfg, batch)
        mamba = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, per) + a.shape).copy(), one
        )
        attn = {
            "k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
            "v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
            "len": jnp.zeros((n_groups,), jnp.int32),
        }
        return {"mamba": mamba, "attn": attn}

    def _run_cached(p, x, positions, cache):
        def body(x, inp):
            gp, gc = inp
            return _group(p["shared"], gp, cfg, x, positions, gc)

        return jax.lax.scan(
            body, x,
            (p["mamba"], {"mamba": cache["mamba"], "attn": cache["attn"]}),
        )

    def prefill(p, batch, cache):
        x = _embed_tokens(p, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x, nc = _run_cached(p, x, positions, cache)
        x = norm_apply(cfg, p["ln_f"], x[:, -1:])
        return _head(p, cfg, x), nc

    def decode_step(p, token, pos, cache):
        x = _embed_tokens(p, token)
        positions = jnp.asarray([pos])
        x, nc = _run_cached(p, x, positions, cache)
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), nc

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ================================================================= VLM family

def _cross_block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg),
        "attn": attn_init(k1, cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(k2, cfg),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _cross_block_apply(p, cfg, x, kv_x=None, kv_cache=None):
    """Gated cross-attention block (llama-3.2-vision style).

    kv_x: image embeddings (prefill/train); kv_cache: precomputed (k, v).
    """
    h = norm_apply(cfg, p["ln1"], x)
    B, S, D = x.shape
    hd = cfg.d_head
    q = dense(p["attn"]["wq"], h).reshape(B, S, cfg.n_heads, hd)
    if kv_cache is None:
        k = dense(p["attn"]["wk"], kv_x).reshape(B, -1, cfg.n_kv_heads, hd)
        v = dense(p["attn"]["wv"], kv_x).reshape(B, -1, cfg.n_kv_heads, hd)
    else:
        k, v = kv_cache["k"], kv_cache["v"]
    from .layers import chunked_attention
    o = chunked_attention(q, k, v, causal=False)
    y = dense(p["attn"]["wo"], o.reshape(B, S, cfg.n_heads * hd))
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * y
    y = mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["ln2"], x))
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * y
    return x, {"k": k, "v": v}


def _build_vlm(cfg: ArchConfig) -> Model:
    per = cfg.cross_attn_every - 1   # self layers per group
    n_groups = cfg.n_layers // cfg.cross_attn_every

    def init_params(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
            "self": jax.vmap(lambda k: stack_init(k, cfg, per))(
                jax.random.split(ks[1], n_groups)
            ),
            "cross": stack_init(ks[2], cfg, n_groups, init_fn=_cross_block_init),
            "ln_f": norm_init(cfg),
            "head": dense_init(ks[3], cfg.d_model, cfg.vocab, scale=0.02),
        }

    def _group(gp_self, gp_cross, x, positions, images, gcache):
        ncache = {}
        if gcache is None:
            def body(x, bp):
                y, _ = block_apply(bp, cfg, x, positions=positions)
                return y, None
            x, _ = jax.lax.scan(body, x, gp_self)
            x, _ = _cross_block_apply(gp_cross, cfg, x, kv_x=images)
            return x, None
        def body(x, inp):
            bp, bc = inp
            return block_apply(bp, cfg, x, positions=positions, cache=bc)
        x, sc = jax.lax.scan(body, x, (gp_self, gcache["self"]))
        ncache["self"] = sc
        kvc = gcache["cross"] if gcache["cross"] is not None else None
        x, kv = _cross_block_apply(gp_cross, cfg, x, kv_x=images, kv_cache=kvc)
        ncache["cross"] = kv
        return x, ncache

    def forward(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        images = batch["images"].astype(jnp.bfloat16)  # (B, n_img, D) stub
        positions = jnp.arange(x.shape[1])

        @jax.checkpoint
        def body(x, inp):
            gs, gc = inp
            y, _ = _group(gs, gc, x, positions, images, None)
            return y, None

        x, _ = jax.lax.scan(body, x, (p["self"], p["cross"]))
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), 0.0

    def init_cache(batch, max_len):
        kv = {
            "k": jnp.zeros((n_groups, per, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
            "v": jnp.zeros((n_groups, per, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
            "len": jnp.zeros((n_groups, per), jnp.int32),
        }
        cross = {
            "k": jnp.zeros((n_groups, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
            "v": jnp.zeros((n_groups, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.d_head),
                           jnp.bfloat16),
        }
        return {"self": kv, "cross": cross}

    def _run_cached(p, x, positions, images, cache):
        def body(x, inp):
            gs, gc, sc = inp
            return _group(gs, gc, x, positions, images, sc)

        return jax.lax.scan(
            body, x,
            (p["self"], p["cross"],
             {"self": cache["self"], "cross": cache["cross"]}),
        )

    def prefill(p, batch, cache):
        x = _embed_tokens(p, batch["tokens"])
        images = batch["images"].astype(jnp.bfloat16)
        positions = jnp.arange(x.shape[1])
        x, nc = _run_cached(p, x, positions, images, cache)
        x = norm_apply(cfg, p["ln_f"], x[:, -1:])
        return _head(p, cfg, x), nc

    def decode_step(p, token, pos, cache):
        x = _embed_tokens(p, token)
        positions = jnp.asarray([pos])
        B = token.shape[0]
        images = jnp.zeros((B, 0, cfg.d_model), jnp.bfloat16)  # unused: kv cached
        x, nc = _run_cached(p, x, positions, images, cache)
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), nc

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ============================================================== encdec family

def _build_encdec(cfg: ArchConfig) -> Model:
    """whisper-style: bidirectional encoder over stub frame embeddings,
    causal decoder with per-layer cross attention."""

    def _dec_block_init(key, cfg):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": norm_init(cfg),
            "self": attn_init(k1, cfg),
            "ln_x": norm_init(cfg),
            "cross": attn_init(k2, cfg),
            "ln2": norm_init(cfg),
            "mlp": mlp_init(k3, cfg),
        }

    def init_params(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
            "enc": stack_init(ks[1], cfg, cfg.n_enc_layers),
            "ln_enc": norm_init(cfg),
            "dec": stack_init(ks[2], cfg, cfg.n_layers, init_fn=_dec_block_init),
            "ln_f": norm_init(cfg),
            "head": dense_init(ks[3], cfg.d_model, cfg.vocab, scale=0.02),
        }

    def encode(p, frames):
        x = frames.astype(jnp.bfloat16) + _sinusoid(frames.shape[1], cfg.d_model)

        @jax.checkpoint
        def body(x, bp):
            y, _ = block_apply(bp, cfg, x, causal=False, use_rope=False)
            return y, None

        x, _ = jax.lax.scan(body, x, p["enc"])
        return norm_apply(cfg, p["ln_enc"], x)

    def _dec_block(bp, cfg, x, mem, positions, cache=None, cross_kv=None):
        nc = {}
        h, sc = attn_apply(bp["self"], cfg, norm_apply(cfg, bp["ln1"], x),
                           positions=positions, use_rope=False,
                           cache=None if cache is None else cache["self"])
        x = x + h
        if cross_kv is not None:
            x2, _ = _cross_from_kv(bp["cross"], cfg, norm_apply(cfg, bp["ln_x"], x), cross_kv)
        else:
            x2, _ = attn_apply(bp["cross"], cfg, norm_apply(cfg, bp["ln_x"], x),
                               kv_x=mem, causal=False, use_rope=False)
        x = x + x2
        x = x + mlp_apply(cfg, bp["mlp"], norm_apply(cfg, bp["ln2"], x))
        if cache is not None:
            nc["self"] = sc
            return x, nc
        return x, None

    def _cross_from_kv(ap, cfg, x, kv):
        B, S, D = x.shape
        hd = cfg.d_head
        q = dense(ap["wq"], x).reshape(B, S, cfg.n_heads, hd)
        from .layers import chunked_attention
        o = chunked_attention(q, kv["k"], kv["v"], causal=False)
        return dense(ap["wo"], o.reshape(B, S, cfg.n_heads * hd)), None

    def forward(p, batch):
        mem = encode(p, batch["frames"])
        tokens = batch["tokens"]
        x = _embed_tokens(p, tokens) + _sinusoid(tokens.shape[1], cfg.d_model)
        positions = jnp.arange(tokens.shape[1])

        @jax.checkpoint
        def body(x, bp):
            return _dec_block(bp, cfg, x, mem, positions)

        x, _ = jax.lax.scan(body, x, p["dec"])
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), 0.0

    def init_cache(batch, max_len):
        return {
            "self": {
                "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                               jnp.bfloat16),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                               jnp.bfloat16),
                "len": jnp.zeros((cfg.n_layers,), jnp.int32),
            },
            "cross": {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads, cfg.d_head),
                               jnp.bfloat16),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads, cfg.d_head),
                               jnp.bfloat16),
            },
        }

    def prefill(p, batch, cache):
        mem = encode(p, batch["frames"])
        # precompute per-layer cross KV once (decode reuses it)
        def xkv(bp):
            B, Sk, D = mem.shape
            k = dense(bp["cross"]["wk"], mem).reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
            v = dense(bp["cross"]["wv"], mem).reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
            return {"k": k, "v": v}

        cross = jax.vmap(xkv)(p["dec"])
        tokens = batch["tokens"]
        x = _embed_tokens(p, tokens) + _sinusoid(tokens.shape[1], cfg.d_model)
        positions = jnp.arange(tokens.shape[1])

        def body(x, inp):
            bp, sc, ckv = inp
            y, nc = _dec_block(bp, cfg, x, None, positions,
                               cache={"self": sc}, cross_kv=ckv)
            return y, nc["self"]

        x, sc = jax.lax.scan(body, x, (p["dec"], cache["self"], cross))
        x = norm_apply(cfg, p["ln_f"], x[:, -1:])
        return _head(p, cfg, x), {"self": sc, "cross": cross}

    def decode_step(p, token, pos, cache):
        x = _embed_tokens(p, token) + _sinusoid_at(pos, cfg.d_model)
        positions = jnp.asarray([pos])

        def body(x, inp):
            bp, sc, ckv = inp
            y, nc = _dec_block(bp, cfg, x, None, positions,
                               cache={"self": sc}, cross_kv=ckv)
            return y, nc["self"]

        x, sc = jax.lax.scan(body, x, (p["dec"], cache["self"], cache["cross"]))
        x = norm_apply(cfg, p["ln_f"], x)
        return _head(p, cfg, x), {"self": sc, "cross": cache["cross"]}

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ==================================================================== builder

_BUILDERS = {
    "dense": _build_dense,
    "moe": _build_moe,
    "ssm": _build_ssm,
    "hybrid": _build_hybrid,
    "vlm": _build_vlm,
    "encdec": _build_encdec,
}


def build_model(cfg: ArchConfig) -> Model:
    try:
        return _BUILDERS[cfg.family](cfg)
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r}")
