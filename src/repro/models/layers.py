"""Shared neural-net layers (pure JAX, functional params-as-pytrees).

Conventions:
* params are nested dicts of jnp arrays; init fns take an rng + config and
  return the dict; apply fns take (params, inputs).
* compute dtype is bf16 (cast at entry), params are stored fp32
  ("master") — the optimizer keeps moments in a configurable dtype.
* attention is *chunked* (online-softmax / FlashAttention-style lax.scan)
  so 32k-token prefill never materializes an (S, S) score matrix.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense",
    "rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm",
    "rope", "chunked_attention", "decode_attention",
    "swiglu_init", "swiglu", "gelu_mlp_init", "gelu_mlp",
    "embed_init",
]


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale)


def dense(w, x):
    return x @ w.astype(x.dtype)


def rmsnorm_init(d: int):
    return jnp.ones((d,), dtype=jnp.float32)


def rmsnorm(g, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * g.astype(x.dtype)


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b"]).astype(x.dtype)


# --------------------------------------------------------------------------
# Activation-sharding hook: the launch layer can register a constraint that
# model code applies at layer boundaries (keeps models mesh-agnostic while
# letting pjit anchor activation shardings instead of relying on pure
# propagation, which replicates batch in practice — see EXPERIMENTS.md §Perf).
_ACT_SHARDING = {"val": None}

# Attention q-chunk sharding (§Perf "sequence-sharded attention"): when kv
# heads don't divide the model axis, head-parallel attention replicates
# compute; sharding the *q-chunk* axis of the chunked-attention map over
# "model" restores full parallelism (kv is small and gets all-gathered).
# Registered as (sharding for the (nq, B, G, R, qc, Dh) stack, target nq).
_ATTN_SHARDING = {"val": None, "nq": None}


def set_activation_sharding(sharding) -> None:
    """Register a NamedSharding for (B, S, D) activations (None to clear)."""
    _ACT_SHARDING["val"] = sharding


def set_attention_sharding(sharding, nq: Optional[int]) -> None:
    """Register q-chunk-axis sharding for chunked attention (None to clear)."""
    _ATTN_SHARDING["val"] = sharding
    _ATTN_SHARDING["nq"] = nq


def constrain_acts(x: jnp.ndarray) -> jnp.ndarray:
    s = _ACT_SHARDING["val"]
    if s is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, s)
    return x


def _constrain_qchunks(x: jnp.ndarray) -> jnp.ndarray:
    s = _ATTN_SHARDING["val"]
    if s is not None and x.ndim == 6:
        return jax.lax.with_sharding_constraint(x, s)
    return x


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, Dh), positions: (S,).

    cos/sin are computed at (S, half) — never broadcast over batch/heads —
    so the saved-for-backward footprint stays negligible.
    """
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freq        # (S, half)
    cos = jnp.cos(ang)[None, :, None, :].astype(x.dtype)       # (1, S, 1, half)
    sin = jnp.sin(ang)[None, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _attend_block(q, k, v, bias):
    """Grouped attention block.

    q: (B,G,R,Tq,Dh), k/v: (B,G,Tk,Dh), bias: (Tq,Tk) additive (fp32).
    R = query heads per kv head (GQA) — kv is never materialized per-head.
    """
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q, k).astype(jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1])) + bias
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)  # rows that are fully masked
    p = jnp.exp(s - m)
    lse = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v.dtype), v)
    return o, m[..., 0], lse[..., 0]


def chunked_attention(
    q: jnp.ndarray,          # (B, Sq, H, Dh)
    k: jnp.ndarray,          # (B, Sk, G, Dh)   G = kv heads
    v: jnp.ndarray,          # (B, Sk, G, Dh)
    causal: bool = True,
    window: Optional[int] = None,   # sliding-window width (tokens), None = full
    q_offset: int = 0,       # absolute position of q[0] (chunked prefill)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax (FlashAttention-style) GQA attention, O(S·chunk) memory.

    The kv scan is wrapped in jax.checkpoint so the backward pass recomputes
    blocks instead of saving every (q_chunk, kv_chunk) score tile.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, G, _ = k.shape
    assert H % G == 0
    rep = H // G

    nq_target = _ATTN_SHARDING["nq"]
    if nq_target and Sq % nq_target == 0 and Sq // nq_target >= 16:
        q_chunk = Sq // nq_target  # align the q-chunk axis with "model"
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad to chunk multiples (mask handles the tail)
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))

    # grouped layout: (B, G, R, S, Dh) for q, (B, G, S, Dh) for kv
    qp = jnp.moveaxis(qp, 2, 1).reshape(B, G, rep, nq * q_chunk, Dh)
    kp = jnp.moveaxis(kp, 2, 1)  # (B, G, Sk, Dh)
    vp = jnp.moveaxis(vp, 2, 1)
    kb = kp.reshape(B, G, nk, kv_chunk, Dh)
    vb = vp.reshape(B, G, nk, kv_chunk, Dh)

    qpos_base = jnp.arange(q_chunk) + q_offset
    kpos_all = jnp.arange(nk * kv_chunk)

    @jax.checkpoint
    def one_q_chunk(qc, qi):
        qpos = qpos_base + qi * q_chunk

        def kv_step(carry, inputs):
            acc, m, lse = carry
            kc, vc, ki = inputs
            kpos = jax.lax.dynamic_slice_in_dim(kpos_all, ki * kv_chunk, kv_chunk)
            valid = (kpos < Sk)[None, :] & (qpos < Sq + q_offset)[:, None]
            if causal:
                valid &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                valid &= kpos[None, :] > (qpos[:, None] - window)
            bias = jnp.where(valid, 0.0, -1e30)
            o, mb, lb = _attend_block(qc, kc, vc, bias)
            m_new = jnp.maximum(m, mb)
            alpha = jnp.exp(m - m_new)
            beta = jnp.exp(mb - m_new)
            acc = acc * alpha[..., None].astype(acc.dtype) + o * beta[..., None].astype(o.dtype)
            lse = lse * alpha + lb * beta
            return (acc, m_new, lse), None

        acc0 = jnp.zeros((B, G, rep, q_chunk, Dh), qc.dtype)
        m0 = jnp.full((B, G, rep, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, G, rep, q_chunk), jnp.float32)
        (acc, m, lse), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nk)),
        )
        return acc / jnp.maximum(lse, 1e-30)[..., None].astype(acc.dtype)

    qcs = qp.reshape(B, G, rep, nq, q_chunk, Dh)
    stacked = jnp.moveaxis(qcs, 3, 0)
    if _ATTN_SHARDING["val"] is not None:
        # sequence-sharded attention (§Perf): q-chunks computed as a
        # *batched* (vmapped) axis so GSPMD can shard it over "model";
        # lax.map would serialize chunks in a while loop instead.
        stacked = _constrain_qchunks(stacked)
        out = jax.vmap(one_q_chunk)(stacked, jnp.arange(nq))
        out = _constrain_qchunks(out)
    else:
        out = jax.lax.map(lambda args: one_q_chunk(*args),
                          (stacked, jnp.arange(nq)))
    # (nq, B, G, rep, q_chunk, Dh) -> (B, Sq, H, Dh)
    out = jnp.moveaxis(out, 0, 3).reshape(B, G, rep, nq * q_chunk, Dh)
    out = out.reshape(B, H, nq * q_chunk, Dh)
    out = jnp.moveaxis(out, 1, 2)[:, :Sq]
    return out


def decode_attention(
    q: jnp.ndarray,       # (B, 1, H, Dh)
    k_cache: jnp.ndarray,  # (B, L, G, Dh)  L = cache length
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray | int,  # number of valid entries
) -> jnp.ndarray:
    """Single-token attention against a KV cache (full or ring)."""
    B, L, G, Dh = k_cache.shape
    H = q.shape[2]
    rep = H // G
    kq = jnp.moveaxis(k_cache, 2, 1)  # (B,G,L,Dh)
    vq = jnp.moveaxis(v_cache, 2, 1)
    qh = jnp.moveaxis(q, 2, 1).reshape(B, G, rep, 1, Dh)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qh, kq).astype(jnp.float32)
    s = s / math.sqrt(Dh)
    pos = jnp.arange(L)
    mask = pos[None, None, None, None, :] < cache_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(vq.dtype)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p, vq)
    return jnp.moveaxis(o.reshape(B, H, 1, Dh), 1, 2)  # (B, 1, H, Dh)


def swiglu_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f),
        "w_up": dense_init(k2, d, f),
        "w_down": dense_init(k3, f, d),
    }


def swiglu(p, x):
    g = dense(p["w_gate"], x)
    u = dense(p["w_up"], x)
    return dense(p["w_down"], jax.nn.silu(g) * u)


def gelu_mlp_init(key, d: int, f: int):
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, f), "w_out": dense_init(k2, f, d)}


def gelu_mlp(p, x):
    return dense(p["w_out"], jax.nn.gelu(dense(p["w_in"], x)))


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
