"""Token-choice top-k Mixture-of-Experts FFN (GShard-style with capacity).

Covers mixtral-8x7b (8 experts, top-2, MoE every layer) and
llama4-maverick (128 experts, top-1, MoE on alternating layers).

Dispatch is scatter-based: per-assignment position-in-expert ranks come
from a cumsum over a one-hot (T·k, E) matrix; tokens beyond the capacity
``C = ceil(cf · T · k / E)`` are dropped (standard GShard semantics).  The
expert GEMMs are grouped einsums over stacked expert weights (E, D, F) —
the TPU-friendly formulation (shardable as EP over the model axis, or TP
inside experts for small E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from ..compat import shard_map
from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "set_moe_block_dispatch"]

# §Perf hook: dispatch tokens in ``n_blocks`` independent blocks whose
# leading axis is sharded over the data axes.  Routing capacity becomes
# per-block (the standard per-device semantics of production MoE stacks),
# and the dispatch scatter/cumsum stays shard-local instead of
# all-reducing a full (E, C, D) expert buffer every layer (measured 2.3
# TB/device/step on mixtral train_4k — EXPERIMENTS.md §Perf).
_MOE_BLOCKS = {"n": None, "sharding": None, "w_in": None, "w_out": None}

# §Perf mixtral iter4: bypass GSPMD auto-partitioning for the MoE layer
# entirely — a shard_map with explicit collectives: per-shard local
# dispatch (local capacity, zero dispatch comms) + TP expert GEMMs with a
# single psum over "model".  mesh/axes registered by the launch layer.
_MOE_SHARD_MAP = {"mesh": None, "dp": None, "tp": None}


def set_moe_block_dispatch(n_blocks, sharding, w_in=None, w_out=None) -> None:
    _MOE_BLOCKS["n"] = n_blocks
    _MOE_BLOCKS["sharding"] = sharding
    _MOE_BLOCKS["w_in"] = w_in
    _MOE_BLOCKS["w_out"] = w_out


def set_moe_shard_map(mesh, dp, tp="model") -> None:
    _MOE_SHARD_MAP["mesh"] = mesh
    _MOE_SHARD_MAP["dp"] = dp
    _MOE_SHARD_MAP["tp"] = tp


def moe_init(key, cfg: ArchConfig):
    k_r, k1, k2, k3 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": dense_init(k_r, d, e, scale=0.02),
        "w_gate": jax.random.normal(k1, (e, d, f), jnp.float32) * (d ** -0.5),
        "w_up": jax.random.normal(k2, (e, d, f), jnp.float32) * (d ** -0.5),
        "w_down": jax.random.normal(k3, (e, f, d), jnp.float32) * (f ** -0.5),
    }


def _dispatch_block(xt, p, cfg: ArchConfig, cap: int):
    """Token-choice top-k dispatch + expert GEMMs for one token block.

    xt: (Tb, D) -> (y: (Tb, D), aux: scalar).
    """
    E, K = cfg.n_experts, cfg.top_k
    Tb, D = xt.shape

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (Tb, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)                # (Tb, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch/GShard)
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_i, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = E * jnp.sum(me * ce)

    # position of each assignment within its expert queue
    eflat = gate_i.reshape(-1)                               # (Tb*K,)
    onehot = jax.nn.one_hot(eflat, E, dtype=jnp.int32)       # (Tb*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, eflat[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, pos, 0)

    # dispatch: (E, C, D) expert buffers
    xt_rep = jnp.repeat(xt, K, axis=0)                       # (Tb*K, D)
    contrib = xt_rep * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E, cap, D), xt.dtype)
    buf = buf.at[eflat, slot].add(contrib)

    # grouped expert GEMMs (ZeRO-3: gather weights bf16 at use time)
    def use(w, kind):
        w = w.astype(xt.dtype)
        s = _MOE_BLOCKS[kind]
        if s is not None and w.ndim == 3:
            w = jax.lax.with_sharding_constraint(w, s)
        return w

    g = jnp.einsum("ecd,edf->ecf", buf, use(p["w_gate"], "w_in"))
    u = jnp.einsum("ecd,edf->ecf", buf, use(p["w_up"], "w_in"))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, use(p["w_down"], "w_out"))

    # combine
    y = out[eflat, slot] * (gate_w.reshape(-1)[:, None] * keep[:, None]).astype(xt.dtype)
    y = y.reshape(Tb, K, D).sum(axis=1)
    return y, aux


def _moe_shard_map_apply(p, cfg: ArchConfig, x: jnp.ndarray):
    """Explicit-collective MoE (mixtral-class, experts replicated, TP on
    d_ff): each (dp, tp) shard dispatches its own tokens locally and the
    row-parallel w_down contraction psums once over the tp axis."""
    from jax.sharding import PartitionSpec as P

    mesh = _MOE_SHARD_MAP["mesh"]
    dp = _MOE_SHARD_MAP["dp"]
    tp = _MOE_SHARD_MAP["tp"]
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[a]
    T_loc = (B // n_dp) * S
    cap = max(int(cfg.capacity_factor * T_loc * K / E), 1)
    cap = min(cap, T_loc)

    def local(xl, router, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, aux = _dispatch_block(xl.reshape(Bl * Sl, D), pl, cfg, cap)
        # row-parallel w_down partial sums -> one psum over tp
        y = jax.lax.psum(y, tp)
        aux = jax.lax.pmean(aux, (dp if isinstance(dp, tuple) else (dp,)) + (tp,))
        return y.reshape(Bl, Sl, D), aux

    bf = jnp.bfloat16
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), P(None, None, tp),
                  P(None, None, tp), P(None, tp, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, p["router"].astype(bf), p["w_gate"].astype(bf),
      p["w_up"].astype(bf), p["w_down"].astype(bf))


def moe_apply(p, cfg: ArchConfig, x: jnp.ndarray):
    """x: (B, S, D) -> (y: (B, S, D), aux_loss: scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S

    if (_MOE_SHARD_MAP["mesh"] is not None
            and cfg.n_experts < _MOE_SHARD_MAP["mesh"].shape[_MOE_SHARD_MAP["tp"]]):
        return _moe_shard_map_apply(p, cfg, x)

    nb = _MOE_BLOCKS["n"] or 1
    if T % nb or (nb > 1 and B % nb):
        nb = 1
    cap = max(int(cfg.capacity_factor * (T // nb) * K / E), 1)
    cap = min(cap, T // nb)

    if nb == 1:
        y, aux = _dispatch_block(x.reshape(T, D), p, cfg, cap)
        return y.reshape(B, S, D), aux

    # block-local dispatch: block axis aligned with the batch sharding
    xb = x.reshape(nb, T // nb, D)
    s = _MOE_BLOCKS["sharding"]
    if s is not None:
        xb = jax.lax.with_sharding_constraint(xb, s)
    y, aux = jax.vmap(lambda t: _dispatch_block(t, p, cfg, cap))(xb)
    if s is not None:
        y = jax.lax.with_sharding_constraint(y, s)
    return y.reshape(B, S, D), jnp.mean(aux)
