"""Dense decoder transformer family (minitron / phi3 / h2o-danube / qwen3)
plus the attention/FFN block primitives reused by the MoE, hybrid, VLM and
enc-dec families.

All stacks scan over stacked layer params (jax.lax.scan) with per-layer
remat — HLO stays small for 100-layer archs and activation memory is
O(layers · layer-boundary), the production choice for 1000+-node meshes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import (
    chunked_attention, decode_attention, dense, dense_init, embed_init,
    gelu_mlp, gelu_mlp_init, layernorm, layernorm_init, rmsnorm, rmsnorm_init,
    rope, swiglu, swiglu_init,
)

__all__ = [
    "attn_init", "attn_apply", "block_init", "block_apply",
    "norm_init", "norm_apply", "mlp_init", "mlp_apply",
    "stack_init", "dense_forward", "dense_init_cache", "dense_decode_step",
    "dense_prefill",
]


# ---------------------------------------------------------------- primitives

def norm_init(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    return rmsnorm_init(d) if cfg.norm == "rmsnorm" else layernorm_init(d)


def norm_apply(cfg: ArchConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def mlp_init(key, cfg: ArchConfig):
    if cfg.mlp == "swiglu":
        return swiglu_init(key, cfg.d_model, cfg.d_ff)
    return gelu_mlp_init(key, cfg.d_model, cfg.d_ff)


def mlp_apply(cfg: ArchConfig, p, x):
    return swiglu(p, x) if cfg.mlp == "swiglu" else gelu_mlp(p, x)


def attn_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.d_head
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(hd)
        p["knorm"] = rmsnorm_init(hd)
    return p


def attn_apply(
    p,
    cfg: ArchConfig,
    x: jnp.ndarray,                   # (B, S, D) queries source
    kv_x: Optional[jnp.ndarray] = None,  # cross-attn memory (B, Sk, D) or None
    positions: Optional[jnp.ndarray] = None,  # (S,) absolute positions of x
    causal: bool = True,
    use_rope: bool = True,
    cache=None,                       # dict(k, v, len) or None
    window: Optional[int] = None,
):
    """Self- or cross-attention.  Returns (y, new_cache).

    Cache modes:
    * cache None, kv from x           -> training / one-shot forward
    * cache given, S > 1              -> prefill (cache is filled)
    * cache given, S == 1             -> decode (ring-buffer write + attend)
    """
    B, S, D = x.shape
    hd = cfg.d_head
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    k = dense(p["wk"], src).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = dense(p["wv"], src).reshape(B, Skv, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q)
        k = rmsnorm(p["knorm"], k)
    if positions is None:
        positions = jnp.arange(S)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = rope(k, positions[:Skv], cfg.rope_theta)

    new_cache = cache
    if cache is not None and S == 1:
        # decode: ring-buffer write at pos % cache_size
        L = cache["k"].shape[1]
        pos = cache["len"]
        slot = pos % L if window is not None else jnp.minimum(pos, L - 1)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        o = decode_attention(q, ck, cv, jnp.minimum(pos + 1, L))
        new_cache = {"k": ck, "v": cv, "len": pos + 1}
    else:
        if cache is not None:
            # prefill: write the (possibly windowed) KV tail into the cache
            L = cache["k"].shape[1]
            kt = k[:, -L:].astype(cache["k"].dtype)
            vt = v[:, -L:].astype(cache["v"].dtype)
            nt = kt.shape[1]
            if window is not None:
                # ring layout: entry for absolute position p lives at p % L
                idx = (positions[-nt:] % L).astype(jnp.int32)
                ck = cache["k"].at[:, idx].set(kt)
                cv = cache["v"].at[:, idx].set(vt)
            else:
                ck = jax.lax.dynamic_update_slice(cache["k"], kt, (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], vt, (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv, "len": cache["len"] + S}
        o = chunked_attention(q, k, v, causal=causal, window=window)
    y = dense(p["wo"], o.reshape(B, S, cfg.n_heads * hd))
    return y, new_cache


def block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg),
        "attn": attn_init(k1, cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(k2, cfg),
    }


def block_apply(p, cfg: ArchConfig, x, positions=None, cache=None,
                causal=True, window=None, kv_x=None, use_rope=True):
    from .layers import constrain_acts

    h, new_cache = attn_apply(
        p["attn"], cfg, norm_apply(cfg, p["ln1"], x), kv_x=kv_x,
        positions=positions, causal=causal, cache=cache, window=window,
        use_rope=use_rope,
    )
    x = constrain_acts(x + h)
    x = constrain_acts(x + mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["ln2"], x)))
    return x, new_cache


# ------------------------------------------------------------- dense stacks

def stack_init(key, cfg: ArchConfig, n: int, init_fn=block_init):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, cfg))(keys)


def dense_params_init(key, cfg: ArchConfig):
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    p = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
        "blocks": stack_init(k_blocks, cfg, cfg.n_layers),
        "ln_f": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        from .layers import dense_init as _di
        p["head"] = _di(k_head, cfg.d_model, cfg.vocab, scale=0.02)
    return p


def _head_logits(p, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        return x @ p["embed"].T.astype(x.dtype)
    return x @ p["head"].astype(x.dtype)


def dense_forward(p, cfg: ArchConfig, tokens: jnp.ndarray,
                  remat: bool = True) -> jnp.ndarray:
    """(B, S) int tokens -> (B, S, V) logits.  Scan over layers + remat."""
    x = p["embed"][tokens].astype(jnp.bfloat16)
    positions = jnp.arange(tokens.shape[1])

    def body(x, layer_p):
        y, _ = block_apply(layer_p, cfg, x, positions=positions,
                           window=cfg.sliding_window)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["blocks"])
    x = norm_apply(cfg, p["ln_f"], x)
    return _head_logits(p, cfg, x)


def dense_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (cfg.n_layers, batch, L, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((cfg.n_layers,), jnp.int32),
    }


def _scan_with_cache(body, x, blocks, cache):
    """Scan over (layer params, layer cache); returns (x, new stacked cache)."""
    def f(x, inp):
        layer_p, layer_c = inp
        y, c = body(x, layer_p, layer_c)
        return y, c

    x, new_cache = jax.lax.scan(f, x, (blocks, cache))
    return x, new_cache


def dense_prefill(p, cfg: ArchConfig, tokens: jnp.ndarray, cache):
    """Prefill: run the full prompt, fill caches, return last-token logits."""
    x = p["embed"][tokens].astype(jnp.bfloat16)
    positions = jnp.arange(tokens.shape[1])

    def body(x, layer_p, layer_c):
        return block_apply(layer_p, cfg, x, positions=positions,
                           cache=layer_c, window=cfg.sliding_window)

    x, new_cache = _scan_with_cache(jax.checkpoint(body), x, p["blocks"], cache)
    x = norm_apply(cfg, p["ln_f"], x[:, -1:])
    return _head_logits(p, cfg, x), new_cache


def dense_decode_step(p, cfg: ArchConfig, token: jnp.ndarray, pos, cache):
    """One decode step.  token: (B, 1) -> logits (B, 1, V), updated cache."""
    x = p["embed"][token].astype(jnp.bfloat16)
    positions = jnp.asarray([pos]) if jnp.ndim(pos) == 0 else pos

    def body(x, layer_p, layer_c):
        return block_apply(layer_p, cfg, x, positions=positions,
                           cache=layer_c, window=cfg.sliding_window)

    x, new_cache = _scan_with_cache(body, x, p["blocks"], cache)
    x = norm_apply(cfg, p["ln_f"], x)
    return _head_logits(p, cfg, x), new_cache