"""Pallas TPU kernel: k_on-step fused 2-D stencil with on-chip (VMEM) reuse.

This is the TPU adaptation of the paper's AN5D-style multi-step kernels
(Sec. III/IV): each grid step DMAs one *overlapping* tile + apron from HBM
into VMEM, applies ``k_on`` time steps entirely in VMEM (the VREG/VMEM
analogue of the paper's register/shared-memory reuse), and writes the tile
back.  The tile aprons are recomputed by neighbouring tiles — the on-chip
incarnation of SO2DR's deliberate redundant computation.

Correctness scheme — *masked in-place centre update*: the VMEM tile keeps
its full shape across steps; each step overwrites the tile centre
``t[r:-r, r:-r]`` with the stencil update, then a global-index mask
re-protects Dirichlet frame cells (row frames if ``keep_top``/
``keep_bottom``; column frames always).  After ``s`` steps a tile cell is
valid iff it is ``>= s*r`` from every tile edge *or* backed by frame, so
tiles are positioned (with clamped DMA starts at band edges) such that the
final output slice is always valid.  The wrapper pads the band to
tile-divisible sizes; pad cells are never read by valid cells.

Semantics match :func:`repro.core.reference.multi_step_band` exactly
(column frames always preserved; ``keep_top``/``keep_bottom`` row frames).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.reference import multi_step_band
from repro.core.stencil import Stencil, get_stencil
from repro.kernels import DEFAULT_TILE, ceil_div

__all__ = ["fused_stencil_band", "DEFAULT_TILE"]


def _kernel(
    x_hbm,
    o_ref,
    tile,
    sem,
    *,
    st: Stencil,
    steps: int,
    keep_top: bool,
    keep_bottom: bool,
    H: int,          # true (unpadded) band height
    X: int,          # true (unpadded) band width
    Hp: int,         # padded band height
    Xp: int,         # padded band width
    TY: int,
    TX: int,
):
    r = st.radius
    m = steps
    TH, TW = TY + 2 * m * r, TX + 2 * m * r
    i = pl.program_id(0)
    j = pl.program_id(1)
    # output-tile origin in input coordinates
    oy = i * TY + (0 if keep_top else m * r)
    ox = j * TX
    # clamped DMA start (tiles at band edges align with the frame)
    sy = jnp.clip(oy - m * r, 0, Hp - TH)
    sx = jnp.clip(ox - m * r, 0, Xp - TW)
    copy = pltpu.make_async_copy(
        x_hbm.at[pl.ds(sy, TH), pl.ds(sx, TW)], tile, sem
    )
    copy.start()
    copy.wait()
    t = tile[...]

    # global-index frame mask: cells that must never update
    grow = sy + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 0)
    gcol = sx + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 1)
    updatable = (gcol >= r) & (gcol < X - r)  # column frames always constant
    if keep_top:
        updatable &= grow >= r
    if keep_bottom:
        updatable &= grow < H - r

    # k_on fused steps, entirely in VMEM (on-chip data reuse)
    for _ in range(m):
        upd = t.at[r:-r, r:-r].set(st.step_valid(t))
        t = jnp.where(updatable, upd, t)
    out = jax.lax.dynamic_slice(t, (oy - sy, ox - sx), (TY, TX))
    o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("name", "steps", "keep_top", "keep_bottom", "tile", "interpret"),
)
def fused_stencil_band(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_top: bool = False,
    keep_bottom: bool = False,
    tile: Tuple[int, int] = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """``steps`` fused stencil time steps on a (H, X) band.

    Drop-in kernel replacement for
    :func:`repro.core.reference.multi_step_band`.
    """
    st = get_stencil(name)
    r, m = st.radius, steps
    H, X = band.shape
    h_out = H - 2 * m * r + (int(keep_top) + int(keep_bottom)) * m * r
    if h_out <= 0:
        raise ValueError(f"band of {H} rows too small for {m} fused steps")

    # effective tile sizes: the DMA region (tile + 2mr apron) must fit
    ty = min(tile[0], h_out)
    tx = min(tile[1], X)
    if H < ty + 2 * m * r or X < tx + 2 * m * r:
        # band smaller than one apron'd tile — tiny-shape fallback
        return multi_step_band(band, name, steps, keep_top, keep_bottom)

    # pad band so every output tile lies fully inside the padded band
    grid = (ceil_div(h_out, ty), ceil_div(X, tx))
    hp_out = grid[0] * ty
    xp_out = grid[1] * tx
    pad_y = hp_out - h_out
    pad_x = xp_out - X
    Hp, Xp = H + pad_y, X + pad_x
    if pad_y or pad_x:
        band = jnp.pad(band, ((0, pad_y), (0, pad_x)))

    kern = functools.partial(
        _kernel,
        st=st,
        steps=m,
        keep_top=keep_top,
        keep_bottom=keep_bottom,
        H=H,
        X=X,
        Hp=Hp,
        Xp=Xp,
        TY=ty,
        TX=tx,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((ty, tx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((hp_out, xp_out), band.dtype),
        scratch_shapes=[
            pltpu.VMEM((ty + 2 * m * r, tx + 2 * m * r), band.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(band)
    return out[:h_out, :X]
