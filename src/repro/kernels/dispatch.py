"""Kernel-dispatch registry: pick the best fused-step implementation.

The paper's win is on *kernel execution* (Sec. V: 2.78x over the
redundancy-free out-of-core code), so which fused-kernel implementation
runs a plan's :class:`~repro.core.plan.FusedKernel` ops matters as much
as the schedule itself.  This module is the single place that knows the
candidates and when each one wins:

=============  ====================================================
impl           when it wins
=============  ====================================================
reference      pure-jnp oracle (:func:`multi_step_band`); fastest on
               CPU/interpret backends, and the numerics ground truth
pallas         VMEM-resident k_on-step kernel — on-chip reuse on TPU
pallas_db      + DMA/compute overlap (two VMEM slots); the steady-state
               TPU choice
mxu            banded-matmul recast; linear stencils whose radius makes
               the VPU path compute-bound (``mxu_wins``)
=============  ====================================================

:func:`select_kernel` resolves a :class:`DispatchPolicy` (``auto`` or an
explicit impl name) against ``(stencil, steps, backend)`` and returns a
``fused_step`` callable with the engine-facing signature
``fn(band, name, steps, keep_top=..., keep_bottom=...)``.  Implementation
modules are imported lazily so the default reference path never pulls
Pallas in.

:func:`modeled_kernel_time` is the autotuner hook: the Sec. III kernel
term specialised per implementation (per-step HBM streaming for the
reference path, tile-apron overhead and DMA/compute serialisation for the
Pallas paths, MXU-flop recast for the banded path), so the dispatch
policy and tile size sweep alongside ``(d, S_TB, k_on, codec)``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.stencil import Stencil, get_stencil
from repro.kernels import DEFAULT_TILE, MXU_TILE, ceil_div

__all__ = [
    "DispatchPolicy", "KernelImpl", "KERNEL_IMPLS",
    "register_kernel_impl", "select_kernel", "modeled_kernel_time",
    "kernel_op_features",
]

# engine-facing fused-step signature:
#   fn(band, stencil_name, steps, keep_top=..., keep_bottom=...) -> band
FusedStep = Callable[..., "jax.Array"]


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    """How the lowering layer resolves FusedKernel ops to device code.

    ``impl``      — registry name, or ``"auto"`` (backend-driven choice).
    ``tile``      — VMEM tile override for the Pallas paths (None = the
                    implementation's default).
    ``interpret`` — force/deny Pallas interpret mode (None = interpret
                    off-TPU, compiled on TPU).
    ``backend``   — override backend detection (``"tpu"``/``"cpu"``/...);
                    None = ``jax.default_backend()``.
    ``bucket``    — let the lowering pass pad band heights to per-plan
                    shape buckets so chunks/rounds share one compiled
                    kernel signature (see :mod:`repro.core.lower`).
    """

    impl: str = "auto"
    tile: Optional[Tuple[int, int]] = None
    interpret: Optional[bool] = None
    backend: Optional[str] = None
    bucket: bool = True


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered fused-kernel implementation."""

    name: str
    description: str
    make: Callable[[DispatchPolicy], FusedStep]   # lazy-imports the module
    supports: Callable[[Stencil, int], bool]      # (stencil, steps) -> ok
    default_tile: Tuple[int, int] = DEFAULT_TILE
    vmem_slots: int = 1      # apron'd tiles resident at once (db = 2)


def _interpret(policy: DispatchPolicy) -> bool:
    if policy.interpret is not None:
        return policy.interpret
    return (policy.backend or jax.default_backend()) != "tpu"


def _make_reference(policy: DispatchPolicy) -> FusedStep:
    from repro.core.reference import multi_step_band

    return multi_step_band


def _make_pallas(policy: DispatchPolicy) -> FusedStep:
    from repro.kernels.stencil_multistep import fused_stencil_band

    tile = policy.tile or DEFAULT_TILE
    interpret = _interpret(policy)

    def step(band, name, steps, keep_top=False, keep_bottom=False):
        return fused_stencil_band(band, name, steps, keep_top=keep_top,
                                  keep_bottom=keep_bottom, tile=tile,
                                  interpret=interpret)

    return step


def _make_pallas_db(policy: DispatchPolicy) -> FusedStep:
    from repro.kernels.stencil_multistep_db import fused_stencil_band_db

    tile = policy.tile or DEFAULT_TILE
    interpret = _interpret(policy)

    def step(band, name, steps, keep_top=False, keep_bottom=False):
        return fused_stencil_band_db(band, name, steps, keep_top=keep_top,
                                     keep_bottom=keep_bottom, tile=tile,
                                     interpret=interpret)

    return step


def _make_mxu(policy: DispatchPolicy) -> FusedStep:
    from repro.kernels.stencil_banded_mxu import banded_fused_stencil

    tile = policy.tile or MXU_TILE
    interpret = _interpret(policy)

    def step(band, name, steps, keep_top=False, keep_bottom=False):
        return banded_fused_stencil(band, name, steps, keep_top=keep_top,
                                    keep_bottom=keep_bottom, tile=tile,
                                    interpret=interpret)

    return step


KERNEL_IMPLS: Dict[str, KernelImpl] = {}


def register_kernel_impl(impl: KernelImpl) -> KernelImpl:
    if impl.name in KERNEL_IMPLS:
        raise ValueError(f"kernel impl {impl.name!r} already registered")
    KERNEL_IMPLS[impl.name] = impl
    return impl


register_kernel_impl(KernelImpl(
    name="reference",
    description="pure-jnp multi_step_band (oracle; per-step HBM streaming)",
    make=_make_reference,
    supports=lambda st, steps: True,
))
register_kernel_impl(KernelImpl(
    name="pallas",
    description="VMEM-resident k_on-step Pallas kernel (on-chip reuse)",
    make=_make_pallas,
    supports=lambda st, steps: True,
))
register_kernel_impl(KernelImpl(
    name="pallas_db",
    description="Pallas kernel with DMA/compute overlap (double buffering)",
    make=_make_pallas_db,
    supports=lambda st, steps: True,
    vmem_slots=2,
))
register_kernel_impl(KernelImpl(
    name="mxu",
    description="banded-matmul MXU recast (linear stencils, high radius)",
    make=_make_mxu,
    supports=lambda st, steps: st.is_linear,
    default_tile=MXU_TILE,
))


def _auto_impl(st: Stencil, backend: str) -> str:
    if backend == "tpu":
        from repro.kernels.stencil_banded_mxu import mxu_wins

        return "mxu" if (st.is_linear and mxu_wins(st)) else "pallas_db"
    # off-TPU (this container, CI) the XLA-fused jnp path beats
    # interpret-mode Pallas by orders of magnitude
    return "reference"


@functools.lru_cache(maxsize=64)
def _resolved_impl(name: str, policy: DispatchPolicy) -> FusedStep:
    """Memoized ``impl.make(policy)``: the same (impl, policy) always
    resolves to the *same callable object*, so the lowering layer's
    signature cache (keyed on the callable's identity) keeps hitting
    across repeated ``lower()`` calls."""
    return KERNEL_IMPLS[name].make(policy)


def select_kernel(
    stencil, steps: int, policy: Optional[DispatchPolicy] = None,
) -> Tuple[str, FusedStep]:
    """Resolve ``(stencil, steps, policy)`` to ``(impl_name, fused_step)``.

    ``policy.impl == "auto"`` picks per backend: MXU recast when
    ``mxu_wins``, the DMA-overlapped Pallas kernel otherwise on TPU, and
    the reference jnp path everywhere else.  An explicit impl name is
    validated against the stencil (e.g. ``mxu`` rejects nonlinear
    stencils at dispatch time, not inside the kernel)."""
    st = get_stencil(stencil) if isinstance(stencil, str) else stencil
    policy = policy or DispatchPolicy()
    backend = policy.backend or jax.default_backend()
    name = policy.impl
    if name == "auto":
        name = _auto_impl(st, backend)
    try:
        impl = KERNEL_IMPLS[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel impl {name!r}; known: {sorted(KERNEL_IMPLS)}")
    if not impl.supports(st, steps):
        raise ValueError(
            f"kernel impl {name!r} does not support stencil {st.name!r} "
            f"(steps={steps})")
    return name, _resolved_impl(name, policy)


# --------------------------------------------------------------- modeling


def _clamped_tile(impl: KernelImpl, tile, h_out: int, X: int) -> Tuple[int, int]:
    ty, tx = tile or impl.default_tile
    return min(ty, h_out), min(tx, X)


def kernel_op_features(impl_name: str, st, shape_in, steps: int,
                       keep_lo, keep_hi, itemsize: int,
                       hw=None, tile: Optional[Tuple[int, int]] = None):
    """Model features of ONE fused call under one implementation.

    Returns ``(mem_bytes, vpu_flops, mxu_flops)`` — the raw quantities
    the Sec. III kernel term divides by hardware rates — or ``None``
    when the implementation is infeasible for this geometry
    (unsupported stencil, non-banded op on a tiled 2-D kernel, or an
    apron'd tile set exceeding a modeled VMEM when ``hw`` is given).
    :func:`modeled_kernel_time` sums these over a plan; the calibration
    harness (:mod:`repro.core.calibrate`) fits measured wall clock
    against the same features, so fitted rates mean exactly what the
    model charges.

    Per-impl memory terms:

    * ``reference`` — no on-chip reuse across fused steps: every step
      streams the band through HBM once (read + write);
    * ``pallas`` / ``pallas_db`` / ``mxu`` — one apron'd tile read per
      output tile plus one exact band write per fused call.
    """
    impl = KERNEL_IMPLS[impl_name]
    if not impl.supports(st, steps):
        return None
    r, m = st.radius, steps
    from repro.core.plan import fused_box_geometry

    shape_out, _, flops, elements = fused_box_geometry(
        r, st.flops_per_elem, shape_in, m, keep_lo, keep_hi, itemsize)
    mem_bytes = 0.0
    mxu_flops = 0.0
    banded = len(shape_in) == 2 and keep_lo[1] and keep_hi[1]
    if impl_name == "reference":
        # per-step band read + write: extents shrink r/step per
        # non-frame side, mirroring fused_box_geometry
        cur = list(shape_in)
        for _ in range(m):
            nxt = [c - 2 * r + (int(kl) + int(kh)) * r
                   for c, kl, kh in zip(cur, keep_lo, keep_hi)]
            mem_bytes += (math.prod(cur) + math.prod(nxt)) * itemsize
            cur = nxt
    elif not banded:
        # the tiled 2-D kernels only run classic row bands; N-D box
        # plans are reference-only for now
        return None
    else:
        h_out, width = shape_out[0], shape_in[1]
        ty, tx = _clamped_tile(impl, tile, h_out, width)
        if ty <= 0 or tx <= 0:
            return None
        apron_bytes = (ty + 2 * m * r) * (tx + 2 * m * r) * itemsize
        c_vmem = getattr(hw, "c_vmem", 0) if hw is not None else 0
        if c_vmem and apron_bytes * impl.vmem_slots > c_vmem:
            return None
        n_tiles = ceil_div(h_out, ty) * ceil_div(width, tx)
        # reads: one apron'd tile per output tile; writes: exact band
        mem_bytes += n_tiles * apron_bytes + h_out * width * itemsize
        if impl_name == "mxu":
            n = 2 * r + 1
            mxu_flops += elements * n * 2 * (tx + 2 * r)
    return mem_bytes, float(flops), mxu_flops


def _profiled_rates(hw, impl_name: str, profile):
    """Hardware rates for one impl, overridden by a fitted
    :class:`~repro.core.calibrate.DeviceProfile` when it carries terms
    for that impl (duck-typed: anything with ``kernel_terms``)."""
    bw, vpu, mxu = hw.bw_dmem, hw.peak_vpu_flops, hw.peak_mxu_flops
    terms = getattr(profile, "kernel_terms", None)
    if terms and impl_name in terms:
        t = terms[impl_name]
        bw = t.get("bw_eff", bw)
        if impl_name == "mxu":
            mxu = t.get("flops_eff", mxu)
        else:
            vpu = t.get("flops_eff", vpu)
    return bw, vpu, mxu


def modeled_kernel_time(plan, hw, impl_name: str,
                        tile: Optional[Tuple[int, int]] = None,
                        profile=None):
    """Sec. III kernel term specialised per implementation.

    Walks the plan's FusedKernel ops, sums their
    :func:`kernel_op_features`, and returns ``(kernel_s, mem_s,
    compute_s)`` — or ``None`` when the implementation is infeasible for
    this plan (unsupported stencil, or the apron'd tile set does not fit
    VMEM on hardware that models a VMEM capacity).

    ``profile`` (a :class:`~repro.core.calibrate.DeviceProfile`)
    replaces the hand-entered HBM bandwidth and FLOP rate with this
    impl's *measured* effective rates when the profile carries a fit for
    it — the measured-cost half of "model proposes, hardware disposes".

    Overlap per impl: ``reference`` and ``pallas_db`` hide DMA under
    compute (``max``); the single-buffered ``pallas`` and the ``mxu``
    recast serialise them (``sum``).
    """
    if impl_name not in KERNEL_IMPLS:
        raise KeyError(
            f"unknown kernel impl {impl_name!r}; known: {sorted(KERNEL_IMPLS)}")
    mem_bytes = 0.0
    vpu_flops = 0.0
    mxu_flops = 0.0
    itemsize = plan.itemsize
    for op in plan.ops:
        if type(op).__name__ != "FusedKernel":
            continue
        st = get_stencil(op.stencil)
        feats = kernel_op_features(impl_name, st, op.shape_in, op.steps,
                                   op.keep_lo, op.keep_hi, itemsize,
                                   hw=hw, tile=tile)
        if feats is None:
            return None
        mem_bytes += feats[0]
        vpu_flops += feats[1]
        mxu_flops += feats[2]
    bw_dmem, peak_vpu, peak_mxu = _profiled_rates(hw, impl_name, profile)
    if impl_name == "mxu":
        compute_s = mxu_flops / peak_mxu
    else:
        compute_s = vpu_flops / peak_vpu
    mem_s = mem_bytes / bw_dmem
    if impl_name in ("reference", "pallas_db"):
        kernel_s = max(mem_s, compute_s)     # XLA / double-buffered overlap
    else:
        kernel_s = mem_s + compute_s         # single-buffered: DMA then compute
    return kernel_s, mem_s, compute_s
