"""Double-buffered variant of the fused stencil kernel.

The paper overlaps CPU↔GPU copies with kernel execution via CUDA streams
(Sec. II, N_strm = 3).  At L0 the TPU analogue is DMA/compute overlap
inside the kernel: two VMEM slots + two DMA semaphores, tile ``g+1``'s
HBM→VMEM copy issued before tile ``g``'s compute so the systolic/vector
units never wait on HBM in steady state.

Grid is 1-D over tiles (row-major) so the pipeline is explicit.  Same
masked in-place centre-update semantics as ``stencil_multistep.py``;
oracle-validated in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.reference import multi_step_band
from repro.core.stencil import Stencil, get_stencil
from repro.kernels import DEFAULT_TILE, ceil_div

__all__ = ["fused_stencil_band_db"]


def _kernel(x_hbm, o_ref, tiles, sems, *, st: Stencil, steps: int,
            keep_top: bool, keep_bottom: bool, H, X, Hp, Xp, TY, TX, NX, NT):
    r = st.radius
    m = steps
    TH, TW = TY + 2 * m * r, TX + 2 * m * r
    g = pl.program_id(0)

    def start(gi, slot):
        i = gi // NX
        j = gi % NX
        oy = i * TY + (0 if keep_top else m * r)
        ox = j * TX
        sy = jnp.clip(oy - m * r, 0, Hp - TH)
        sx = jnp.clip(ox - m * r, 0, Xp - TW)
        pltpu.make_async_copy(
            x_hbm.at[pl.ds(sy, TH), pl.ds(sx, TW)],
            tiles.at[slot], sems.at[slot],
        ).start()
        return sy, sx

    # prologue: first tile fetches itself
    @pl.when(g == 0)
    def _():
        start(g, g % 2)

    # steady state: prefetch the NEXT tile into the other slot
    @pl.when(g + 1 < NT)
    def _():
        start(g + 1, (g + 1) % 2)

    # wait for this tile's DMA (recompute its descriptor for the wait)
    i = g // NX
    j = g % NX
    oy = i * TY + (0 if keep_top else m * r)
    ox = j * TX
    sy = jnp.clip(oy - m * r, 0, Hp - TH)
    sx = jnp.clip(ox - m * r, 0, Xp - TW)
    pltpu.make_async_copy(
        x_hbm.at[pl.ds(sy, TH), pl.ds(sx, TW)],
        tiles.at[g % 2], sems.at[g % 2],
    ).wait()

    t = tiles[g % 2]
    grow = sy + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 0)
    gcol = sx + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 1)
    updatable = (gcol >= r) & (gcol < X - r)
    if keep_top:
        updatable &= grow >= r
    if keep_bottom:
        updatable &= grow < H - r
    for _ in range(m):
        upd = t.at[r:-r, r:-r].set(st.step_valid(t))
        t = jnp.where(updatable, upd, t)
    o_ref[...] = jax.lax.dynamic_slice(t, (oy - sy, ox - sx), (TY, TX))


@functools.partial(
    jax.jit,
    static_argnames=("name", "steps", "keep_top", "keep_bottom", "tile", "interpret"),
)
def fused_stencil_band_db(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_top: bool = False,
    keep_bottom: bool = False,
    tile: Tuple[int, int] = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    st = get_stencil(name)
    r, m = st.radius, steps
    H, X = band.shape
    h_out = H - 2 * m * r + (int(keep_top) + int(keep_bottom)) * m * r
    if h_out <= 0:
        raise ValueError(f"band of {H} rows too small for {m} fused steps")
    ty = min(tile[0], h_out)
    tx = min(tile[1], X)
    if H < ty + 2 * m * r or X < tx + 2 * m * r:
        return multi_step_band(band, name, steps, keep_top, keep_bottom)

    ny, nx = ceil_div(h_out, ty), ceil_div(X, tx)
    hp_out, xp_out = ny * ty, nx * tx
    pad_y, pad_x = hp_out - h_out, xp_out - X
    Hp, Xp = H + pad_y, X + pad_x
    if pad_y or pad_x:
        band = jnp.pad(band, ((0, pad_y), (0, pad_x)))

    kern = functools.partial(
        _kernel, st=st, steps=m, keep_top=keep_top, keep_bottom=keep_bottom,
        H=H, X=X, Hp=Hp, Xp=Xp, TY=ty, TX=tx, NX=nx, NT=ny * nx,
    )
    out = pl.pallas_call(
        kern,
        grid=(ny * nx,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((ty, tx), lambda g: (g // nx, g % nx)),
        out_shape=jax.ShapeDtypeStruct((hp_out, xp_out), band.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, ty + 2 * m * r, tx + 2 * m * r), band.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(band)
    return out[:h_out, :X]
