"""Pallas TPU kernels for the paper's compute hot-spot.

* stencil_multistep     — k_on-step fused kernel (VMEM-resident steps)
* stencil_multistep_db  — + DMA/compute overlap (double buffering)
* stencil_banded_mxu    — beyond-paper MXU recast for high radii
* dispatch              — registry selecting the best implementation per
                          (stencil kind, radius, steps, backend)
* ops                   — jit'd wrappers;  ref — pure-jnp oracles

Shared tiling constants/helpers live here so the three kernel modules
agree on one definition (they used to carry private copies).
"""
from __future__ import annotations

__all__ = ["DEFAULT_TILE", "MXU_TILE", "ceil_div"]

# default VMEM tile for the VPU kernels (rows, lanes)
DEFAULT_TILE = (256, 512)
# MXU-native tile: lane dim 128 matches the systolic array
MXU_TILE = (DEFAULT_TILE[0], 128)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)
