"""Pallas TPU kernels for the paper's compute hot-spot.

* stencil_multistep     — k_on-step fused kernel (VMEM-resident steps)
* stencil_multistep_db  — + DMA/compute overlap (double buffering)
* stencil_banded_mxu    — beyond-paper MXU recast for high radii
* ops                   — jit'd wrappers;  ref — pure-jnp oracles
"""
