"""Pure-jnp oracles for every kernel in this package.

The fused-stencil oracle is the band semantics from
:mod:`repro.core.reference`; re-exported here so kernel tests depend only on
``repro.kernels``.
"""
from __future__ import annotations

from repro.core.reference import multi_step_band, step_band  # noqa: F401

__all__ = ["multi_step_band", "step_band"]
