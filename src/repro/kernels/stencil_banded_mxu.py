"""MXU-banded fused stencil kernel (beyond-paper, EXPERIMENTS.md §4.3).

On v5e the VPU (3.9 TFLOP/s fp32) makes high-radius box stencils
compute-bound at a single step (DESIGN.md §2), killing the paper's fusion
win for box2d3r/4r.  This kernel re-casts each time step of a *linear*
stencil as ``(2r+1)`` banded matmuls that run on the 197 TFLOP/s MXU:

    out = sum_dy  shift_dy(tile) @ B_dy,     B_dy[x+dx, x] = c[dy, dx]

Efficiency per output element = (2r+1) · 2 · (TX + 2r) MXU-flops vs
``2(2r+1)^2`` VPU-flops.  With TX = 128 (MXU-native) the MXU path wins
when  (2r+1)·2·(TX+2r)/197e12  <  2(2r+1)^2/3.9e12, i.e. radius >= 3:
box2d4r 2448/197T = 12.4 ps vs 161/3.9T = 41 ps  (~3.3x).

Same masked in-place centre-update validity scheme as
``stencil_multistep.py``; identical band semantics; oracle-validated in
interpret mode (`tests/test_kernels.py::test_banded_mxu_kernel`).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.reference import multi_step_band
from repro.core.stencil import Stencil, get_stencil
from repro.kernels import MXU_TILE, ceil_div

__all__ = ["banded_fused_stencil", "mxu_wins"]

DEFAULT_TILE = MXU_TILE  # lane dim 128 = MXU-native


def mxu_wins(st: Stencil, tx: int = 128,
             vpu: float = 3.9e12, mxu: float = 197e12) -> bool:
    """Napkin check: does the banded-MXU recast beat the VPU path?"""
    if not st.is_linear:
        return False
    n = 2 * st.radius + 1
    t_mxu = n * 2 * (tx + 2 * st.radius) / mxu
    t_vpu = st.flops_per_elem / vpu
    return t_mxu < t_vpu


def _band_matrices(st: Stencil, tx: int) -> np.ndarray:
    """(2r+1, TX+2r, TX) banded matrices, one per row offset dy."""
    r = st.radius
    n = 2 * r + 1
    out = np.zeros((n, tx + 2 * r, tx), np.float32)
    for dy in range(n):
        for dx in range(n):
            c = float(st.coeffs[dy, dx])
            for x in range(tx):
                out[dy, x + dx, x] = c
    return out


def _kernel(x_hbm, bands_ref, o_ref, tile, sem, *, st, steps, keep_top,
            keep_bottom, H, X, Hp, Xp, TY, TX):
    r = st.radius
    m = steps
    n = 2 * r + 1
    TH, TW = TY + 2 * m * r, TX + 2 * m * r
    i = pl.program_id(0)
    j = pl.program_id(1)
    oy = i * TY + (0 if keep_top else m * r)
    ox = j * TX
    sy = jnp.clip(oy - m * r, 0, Hp - TH)
    sx = jnp.clip(ox - m * r, 0, Xp - TW)
    copy = pltpu.make_async_copy(
        x_hbm.at[pl.ds(sy, TH), pl.ds(sx, TW)], tile, sem
    )
    copy.start()
    copy.wait()
    t = tile[...]

    grow = sy + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 0)
    gcol = sx + jax.lax.broadcasted_iota(jnp.int32, (TH, TW), 1)
    updatable = (gcol >= r) & (gcol < X - r)
    if keep_top:
        updatable &= grow >= r
    if keep_bottom:
        updatable &= grow < H - r

    bands = bands_ref[...]
    for s in range(m):
        # centre via (2r+1) banded matmuls on the MXU; band matrices map
        # the full tile width TW onto the centre TW - 2r
        acc = None
        for dy in range(n):
            rows = t[dy : TH - (n - 1) + dy, :]          # (TH-2r, TW)
            term = jnp.dot(rows, bands[dy].astype(t.dtype),
                           preferred_element_type=jnp.float32)
            acc = term if acc is None else acc + term
        upd = t.at[r:-r, r:-r].set(acc.astype(t.dtype))
        t = jnp.where(updatable, upd, t)
    out = jax.lax.dynamic_slice(t, (oy - sy, ox - sx), (TY, TX))
    o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("name", "steps", "keep_top", "keep_bottom", "tile", "interpret"),
)
def banded_fused_stencil(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_top: bool = False,
    keep_bottom: bool = False,
    tile: Tuple[int, int] = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Drop-in alternative to ``fused_stencil_band`` for linear stencils."""
    st = get_stencil(name)
    if not st.is_linear:
        raise ValueError(f"{name} is nonlinear; banded-MXU path needs coeffs")
    r, m = st.radius, steps
    H, X = band.shape
    h_out = H - 2 * m * r + (int(keep_top) + int(keep_bottom)) * m * r
    if h_out <= 0:
        raise ValueError(f"band of {H} rows too small for {m} fused steps")

    ty = min(tile[0], h_out)
    tx = min(tile[1], X)
    if H < ty + 2 * m * r or X < tx + 2 * m * r:
        return multi_step_band(band, name, steps, keep_top, keep_bottom)

    grid = (ceil_div(h_out, ty), ceil_div(X, tx))
    hp_out, xp_out = grid[0] * ty, grid[1] * tx
    pad_y, pad_x = hp_out - h_out, xp_out - X
    Hp, Xp = H + pad_y, X + pad_x
    if pad_y or pad_x:
        band = jnp.pad(band, ((0, pad_y), (0, pad_x)))

    # band matrices: (n, TW, TW-2r) — full tile width in, centre width out,
    # passed as a (small) VMEM-resident input replicated to every tile
    tw = tx + 2 * m * r
    bands = jnp.asarray(_band_matrices(st, tw - 2 * r))

    kern = functools.partial(
        _kernel, st=st, steps=m, keep_top=keep_top,
        keep_bottom=keep_bottom, H=H, X=X, Hp=Hp, Xp=Xp, TY=ty, TX=tx,
    )
    n = 2 * r + 1
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((n, tw, tw - 2 * r), lambda i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((ty, tx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((hp_out, xp_out), band.dtype),
        scratch_shapes=[
            pltpu.VMEM((ty + 2 * m * r, tx + 2 * m * r), band.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(band, bands)
    return out[:h_out, :X]
