"""Jit'd public wrappers for the Pallas kernels.

``fused_stencil`` auto-selects interpret mode off-TPU so the same call site
works on this CPU container (validation) and on a real TPU (deployment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .stencil_multistep import DEFAULT_TILE, fused_stencil_band

__all__ = ["fused_stencil", "kernel_fused_step"]


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_stencil(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_top: bool = False,
    keep_bottom: bool = False,
    tile=DEFAULT_TILE,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return fused_stencil_band(
        band, name, steps, keep_top=keep_top, keep_bottom=keep_bottom,
        tile=tile, interpret=interpret,
    )


def kernel_fused_step(band, name, steps, keep_top=False, keep_bottom=False):
    """Signature-compatible ``fused_step`` for the out-of-core engines
    (:mod:`repro.core.oocore`), backed by the Pallas kernel."""
    return fused_stencil(band, name, steps, keep_top=keep_top, keep_bottom=keep_bottom)
