"""Fault-tolerant checkpointing: atomic writes, keep-K, bitwise resume.

Layout:  <dir>/step_<n>/
            arrays.npz        flattened pytree leaves ("/"-joined keys)
            meta.json         step, leaf treedef, mesh + config fingerprints

Writes go to ``step_<n>.tmp`` and are atomically renamed, so a job killed
mid-save never corrupts the restore point (the previous step remains
valid).  Every payload file is fsync'd before the rename and the parent
directory is fsync'd after it, so a *machine* crash (not just a process
kill) cannot publish a step whose bytes never reached disk; ``meta.json``
is written last and doubles as the completeness marker —
``all_steps``/``restore`` skip any step directory missing it or the
arrays payload.  ``restore`` returns leaves as numpy; the caller
re-places them onto the current mesh (see launch/elastic.py for
re-sharding onto a *different* mesh/device count — elastic restart).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> dict:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    rec("", tree)
    return flat


def _unflatten(flat: dict, like):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, tuple):
            kids = [rec(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            if hasattr(node, "_fields"):   # NamedTuple (e.g. OptState)
                return type(node)(*kids)
            return tuple(kids)
        if isinstance(node, list):
            return [rec(f"{prefix}/{i}", v) for i, v in enumerate(node)]
        return flat[prefix]

    return rec("", like)


def _fsync_path(path: str) -> None:
    """fsync a file or directory; directory fsync is what makes the
    rename itself durable.  Best-effort on filesystems that refuse
    directory fds (some network mounts)."""
    flags = os.O_RDONLY | (os.O_DIRECTORY if os.path.isdir(path) else 0)
    try:
        fd = os.open(path, flags)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _complete(self, step: int) -> bool:
        d = self._step_dir(step)
        return (os.path.exists(os.path.join(d, "meta.json"))
                and os.path.exists(os.path.join(d, "arrays.npz")))

    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        flat = _flatten(host_tree)
        # arrays first, meta last: meta.json is the completeness marker
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        meta = {"step": step, "n_leaves": len(flat)}
        meta.update(extra_meta or {})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _fsync_path(self.dir)  # make the rename itself durable
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self):
        """Published *complete* steps — a directory missing its payload
        or its meta marker (a crash artifact) is invisible to restore."""
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    step = int(name.split("_")[1])
                except ValueError:
                    continue
                if self._complete(step):
                    out.append(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, dict]:
        """Restore into the structure of ``like``; returns (tree, meta)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return _unflatten(flat, like), meta
