"""Pure-jnp oracle for stencil computation.

This is the ground truth every engine and kernel is validated against.  It
uses the interior-update convention: the ``r``-wide frame is Dirichlet
(constant in time); only interior elements update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .stencil import Stencil, get_stencil

__all__ = [
    "step_domain",
    "run_reference",
    "step_band",
    "multi_step_band",
    "step_band_nd",
    "multi_step_box",
]


def step_domain(x: jnp.ndarray, st: Stencil) -> jnp.ndarray:
    """One time step on the full framed domain (shape-preserving)."""
    r = st.radius
    idx = (Ellipsis,) + (slice(r, -r),) * st.ndim
    return x.at[idx].set(st.step_valid(x))


@functools.partial(jax.jit, static_argnames=("name", "n"))
def _run_reference_jit(x: jnp.ndarray, name: str, n: int) -> jnp.ndarray:
    st = get_stencil(name)
    return jax.lax.fori_loop(0, n, lambda _, a: step_domain(a, st), x)


def run_reference(x: jnp.ndarray, st: Stencil, n: int) -> jnp.ndarray:
    """n reference time steps on the framed domain."""
    return _run_reference_jit(x, st.name, n)


def step_band(
    band: jnp.ndarray, st: Stencil, keep_top: bool, keep_bottom: bool
) -> jnp.ndarray:
    """One step on a horizontal band of rows.

    ``band`` is (H, X) — full domain width (left/right frame columns
    included), an arbitrary contiguous row range.  The output covers the rows
    whose update is computable, i.e. the band shrinks by ``r`` rows at each
    side unless that side is the domain frame (``keep_*``), in which case the
    frame rows are passed through unchanged.

    output height = H - 2r + (keep_top + keep_bottom) * r
    """
    r = st.radius
    h = band.shape[0]
    interior = band[r : h - r].at[:, r:-r].set(st.step_valid(band))
    parts = []
    if keep_top:
        parts.append(band[:r])
    parts.append(interior)
    if keep_bottom:
        parts.append(band[h - r :])
    return jnp.concatenate(parts, axis=0) if len(parts) > 1 else interior


@functools.partial(jax.jit, static_argnames=("name", "steps", "keep_top", "keep_bottom"))
def multi_step_band(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_top: bool = False,
    keep_bottom: bool = False,
) -> jnp.ndarray:
    """``steps`` fused time steps on a band (compute area shrinks r/step).

    This is the *reference* for the fused k_on-step kernel: the Pallas
    implementation in :mod:`repro.kernels` must match it.
    """
    st = get_stencil(name)
    for _ in range(steps):
        band = step_band(band, st, keep_top, keep_bottom)
    return band


def step_band_nd(
    band: jnp.ndarray, st: Stencil, keep_lo, keep_hi
) -> jnp.ndarray:
    """One step on an N-D box band (the :func:`step_band` generalization).

    Every axis carries ``r`` apron cells per side; the output drops each
    side's apron unless that side is the domain frame (``keep_lo[a]`` /
    ``keep_hi[a]``), in which case the frame cells pass through unchanged:

        out extent[a] = S[a] - 2r + (keep_lo[a] + keep_hi[a]) * r
    """
    r = st.radius
    valid = st.step_valid(band)
    full = band.at[tuple(slice(r, s - r) for s in band.shape)].set(valid)
    crop = tuple(
        slice(0 if kl else r, s if kh else s - r)
        for s, kl, kh in zip(band.shape, keep_lo, keep_hi)
    )
    return full[crop]


@functools.partial(
    jax.jit, static_argnames=("name", "steps", "keep_lo", "keep_hi"))
def multi_step_box(
    band: jnp.ndarray,
    name: str,
    steps: int,
    keep_lo: tuple = (),
    keep_hi: tuple = (),
) -> jnp.ndarray:
    """``steps`` fused time steps on an N-D box band.

    The reference kernel for non-banded :class:`~repro.core.plan.FusedKernel`
    ops (3-D tiles, column chunks): compute volume shrinks ``r`` per step
    on every non-frame side, matching
    :func:`repro.core.plan.fused_box_geometry`."""
    st = get_stencil(name)
    for _ in range(steps):
        band = step_band_nd(band, st, keep_lo, keep_hi)
    return band
