"""Geometry-only TransferStats prediction — a dry run of the plan.

Since the plan/execute refactor, every engine compiles its schedule into
an :class:`repro.core.plan.ExecutionPlan` whose accounting is derived
from the op stream itself, so "prediction" and "measurement" are the same
arithmetic by construction: this module simply compiles the plan (no
array allocation — the paper's full 11 GB workloads, 38400^2 fp32, cost
microseconds) and walks it with the dry-run executor.
``tests/test_accounting.py`` asserts bit-equality with the stats the real
engines produce on small domains.
"""
from __future__ import annotations

from .executor import DryRunExecutor
from .oocore import TransferStats, compile_plan
from .stencil import Stencil

__all__ = ["predict_stats"]


def predict_stats(
    engine: str, st: Stencil, Y: int, X: int, n: int,
    d: int, k_off: int, k_on: int, itemsize: int = 4, codec=None,
) -> TransferStats:
    plan = compile_plan(engine, st, Y, X, n, d, k_off, k_on, itemsize,
                        codec=codec)
    _, stats = DryRunExecutor().execute(plan)
    return stats
