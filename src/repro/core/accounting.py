"""Geometry-only TransferStats prediction.

Re-implements each engine's accounting loop without allocating the domain,
so benchmarks can evaluate the paper's full 11 GB workloads (38400^2 fp32)
instantly.  ``tests/test_accounting.py`` asserts bit-equality with the
stats the real engines produce on small domains.
"""
from __future__ import annotations

from .oocore import TransferStats, _account_fused
from .stencil import Stencil
from .tiling import make_chunk_plan, split_steps

__all__ = ["predict_stats"]


def predict_stats(
    engine: str, st: Stencil, Y: int, X: int, n: int,
    d: int, k_off: int, k_on: int, itemsize: int = 4,
) -> TransferStats:
    r = st.radius
    stats = TransferStats()
    stats.exact_elements = n * (Y - 2 * r) * (X - 2 * r)

    if engine == "incore":
        stats.h2d_bytes = Y * X * itemsize
        stats.d2h_bytes = Y * X * itemsize
        h = Y
        for m in split_steps(n, k_on):
            h0 = Y
            _account_fused(stats, st, h0, X, m, True, True, itemsize)
        return stats

    plan = make_chunk_plan(Y, X, r, d)
    if k_off > plan.max_k_off():
        raise ValueError("infeasible k_off")

    for k in split_steps(n, k_off):
        for i, cb in enumerate(plan.chunks):
            first, last = i == 0, i == plan.d - 1
            if engine == "naive_tb":
                lo = 0 if first else cb.a - k * r
                hi = Y if last else cb.b + k * r
                stats.h2d_bytes += (hi - lo) * X * itemsize
                h = hi - lo
                for m in split_steps(k, k_on):
                    h = _account_fused(stats, st, h, X, m, first, last, itemsize)
                stats.d2h_bytes += cb.rows * X * itemsize
            elif engine == "so2dr":
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                stats.h2d_bytes += (hi - lo) * X * itemsize
                if first:
                    h = hi - lo
                else:
                    stats.buffer_bytes += 2 * k * r * X * itemsize  # read
                    h = (hi - lo) + 2 * k * r
                if not last:
                    stats.buffer_bytes += 2 * k * r * X * itemsize  # write
                for m in split_steps(k, k_on):
                    h = _account_fused(stats, st, h, X, m, first, last, itemsize)
                stats.d2h_bytes += cb.rows * X * itemsize
            elif engine == "resreu":
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                stats.h2d_bytes += (hi - lo) * X * itemsize
                W_h = hi - lo
                for s in range(k):
                    if not last:
                        stats.buffer_bytes += 2 * r * X * itemsize  # write
                    if first:
                        inp_h = W_h
                    else:
                        stats.buffer_bytes += 2 * r * X * itemsize  # read
                        inp_h = W_h + 2 * r
                    _account_fused(stats, st, inp_h, X, 1, first, last, itemsize)
                    W_h = inp_h - 2 * r + (int(first) + int(last)) * r
                stats.d2h_bytes += cb.rows * X * itemsize
            else:
                raise KeyError(engine)
    return stats
