"""Measured-cost calibration: fit the Sec. III model to this machine.

The analytic model (:mod:`repro.core.analytic`) and the per-impl kernel
terms (:func:`repro.kernels.dispatch.modeled_kernel_time`) run on
hand-entered :class:`~repro.core.analytic.Hardware` constants, yet tuned
parameters do not transfer across chips (arXiv 2406.08923) and the codec
wire models are asserted rather than measured (arXiv 2204.11315).  This
module closes the loop: it runs kernel/transfer/codec microbenchmarks on
the *current* backend, least-squares-fits the model terms, and persists
the result as a versioned per-device :class:`DeviceProfile` that drops in
anywhere a ``Hardware`` is accepted — the autotuner
(:func:`repro.core.tune.tune`), the serving admission price
(:class:`repro.serve.service.StencilService`), and the benchmark CLIs.

Fits are deliberately simple and auditable:

* **interconnect** — host->device and device->host round trips over a
  size ladder fit ``t = t_lat + bytes / bw`` (the intercept doubles as
  the collective-launch latency proxy ``t_ici_latency``);
* **off-chip memory** — a device-side read+write streaming op fits
  ``t = t0 + 2 * bytes / bw_dmem``;
* **kernel terms, per impl** — fused-step calls over a band ladder fit
  the two-term roofline ``t ~= mem_bytes / bw_eff + flops / flops_eff``
  (non-negative by construction: a negative coefficient falls back to
  the single dominant term);
* **codec throughput** — encode/decode wall clock over a size ladder
  fits bytes/s per registered codec.

Every fit records its relative RMS residual; the CI gate
(``benchmarks/check_regression.py --profile``) rejects profiles with
non-positive terms or residuals above the ceiling — a fit that does not
describe the machine must not silently price serving deadlines.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .analytic import Hardware, TPU_V5E

__all__ = [
    "DeviceProfile", "ProfileError", "backend_fingerprint",
    "fit_affine", "fit_two_term",
    "measure_interconnect", "measure_dmem", "measure_kernel_impl",
    "measure_codec", "calibrate", "resolve_hardware",
    "PROFILE_SCHEMA_VERSION",
]

PROFILE_SCHEMA_VERSION = 1

# floors applied after fitting: a degenerate microbenchmark (timer
# granularity, empty ladder) must still produce a *loadable* profile
# whose terms the sanity gate can reason about
_MIN_RATE = 1.0          # bytes/s or flop/s — strictly positive terms
_EPS_T = 1e-9            # seconds; guards zero-division on fast timers


class ProfileError(ValueError):
    """A persisted profile is unreadable or from an unknown schema."""


# --------------------------------------------------------------- fitting


def fit_affine(xs: Sequence[float], ts: Sequence[float],
               ) -> Tuple[float, float, float]:
    """Least-squares fit of ``t = t0 + x / rate``.

    Returns ``(t0, rate, residual)`` with ``t0 >= 0`` and ``rate > 0``:
    a non-positive slope (noise on a too-small ladder) falls back to the
    zero-intercept fit ``rate = sum(x*t) / sum(x*x)``; the residual is
    the relative RMS error of the clamped fit over the sample."""
    xs = np.asarray(xs, dtype=np.float64)
    ts = np.asarray(ts, dtype=np.float64)
    if xs.size == 0:
        raise ValueError("fit_affine needs at least one sample")
    A = np.stack([np.ones_like(xs), xs], axis=1)
    (t0, slope), *_ = np.linalg.lstsq(A, ts, rcond=None)
    if slope <= 0 or t0 < 0:
        slope = float(np.dot(xs, ts) / max(np.dot(xs, xs), _EPS_T))
        t0 = 0.0
    slope = max(slope, 1.0 / 1e18)          # rate ceiling 1e18 units/s
    rate = 1.0 / slope
    pred = t0 + xs * slope
    resid = _rel_rms(pred, ts)
    return float(t0), float(rate), resid


def fit_two_term(m1: Sequence[float], m2: Sequence[float],
                 ts: Sequence[float]) -> Tuple[float, float, float]:
    """Least-squares fit of ``t = m1 / rate1 + m2 / rate2``.

    The additive form is the fittable surrogate of the roofline
    ``max(mem, compute)`` (it upper-bounds it within 2x and is linear in
    the unknowns).  Negative coefficients — collinear features on a
    small ladder — fall back to the dominant single term, with the other
    rate pinned effectively infinite.  Returns
    ``(rate1, rate2, residual)``, both rates strictly positive."""
    m1 = np.asarray(m1, dtype=np.float64)
    m2 = np.asarray(m2, dtype=np.float64)
    ts = np.asarray(ts, dtype=np.float64)
    if m1.size == 0:
        raise ValueError("fit_two_term needs at least one sample")
    A = np.stack([m1, m2], axis=1)
    coef, *_ = np.linalg.lstsq(A, ts, rcond=None)
    if np.any(coef <= 0):
        # refit on the feature that explains more of the signal
        c1 = float(np.dot(m1, ts) / max(np.dot(m1, m1), _EPS_T))
        c2 = float(np.dot(m2, ts) / max(np.dot(m2, m2), _EPS_T))
        e1 = _rel_rms(m1 * c1, ts)
        e2 = _rel_rms(m2 * c2, ts)
        coef = np.array([c1, 1e-18] if e1 <= e2 else [1e-18, c2])
    coef = np.maximum(coef, 1e-18)
    pred = A @ coef
    resid = _rel_rms(pred, ts)
    return float(1.0 / coef[0]), float(1.0 / coef[1]), resid


def _rel_rms(pred: np.ndarray, ts: np.ndarray) -> float:
    err = (pred - ts) / np.maximum(np.abs(ts), _EPS_T)
    return float(np.sqrt(np.mean(err * err)))


def _best_of(fn, iters: int) -> float:
    """Minimum wall clock over ``iters`` calls (after one warmup) —
    the standard microbenchmark noise filter."""
    fn()
    best = math.inf
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return max(best, _EPS_T)


# --------------------------------------------------------- measurements


def backend_fingerprint() -> Dict[str, object]:
    """Identity of the backend this profile was measured on — enough to
    refuse a stale profile on a different machine class."""
    import platform

    import jax

    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_kind": str(getattr(dev, "device_kind", dev.platform)),
        "device_count": int(jax.device_count()),
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "machine": platform.machine(),
        "python": platform.python_version(),
    }


def measure_interconnect(sizes: Sequence[int], iters: int = 3,
                         seed: int = 0) -> List[Tuple[int, float, float]]:
    """Host->device and device->host round trips per payload size.

    Returns ``(nbytes, t_h2d, t_d2h)`` per rung.  On a CPU backend the
    "interconnect" is a memcpy — that is the honest number for plans
    executed here, and exactly what the acceptance drill asks for."""
    import jax

    rng = np.random.default_rng(seed)
    out = []
    for nbytes in sizes:
        n = max(int(nbytes) // 4, 1)
        x = rng.standard_normal(n).astype(np.float32)
        t_h2d = _best_of(
            lambda: jax.block_until_ready(jax.device_put(x)), iters)
        xd = jax.block_until_ready(jax.device_put(x))
        t_d2h = _best_of(lambda: np.asarray(xd), iters)
        out.append((n * 4, t_h2d, t_d2h))
    return out


def measure_dmem(sizes: Sequence[int], iters: int = 3,
                 seed: int = 0) -> List[Tuple[int, float]]:
    """Device-side streaming (one read + one write of ``nbytes``)."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    add_one = jax.jit(lambda a: a + 1.0)
    out = []
    for nbytes in sizes:
        n = max(int(nbytes) // 4, 1)
        xd = jax.block_until_ready(
            jnp.asarray(rng.standard_normal(n).astype(np.float32)))
        t = _best_of(lambda: jax.block_until_ready(add_one(xd)), iters)
        out.append((n * 4, t))
    return out


def measure_kernel_impl(impl: str, stencil: str,
                        bands: Sequence[Tuple[int, int]],
                        steps_grid: Sequence[int], iters: int = 2,
                        seed: int = 0,
                        ) -> List[Tuple[float, float, float]]:
    """Fused-step wall clock per (band, steps) point for one registered
    kernel implementation.

    Returns ``(mem_bytes, flops, t)`` samples whose features come from
    :func:`repro.kernels.dispatch.kernel_op_features` — byte-for-byte
    the quantities :func:`~repro.kernels.dispatch.modeled_kernel_time`
    charges for this impl — so the fitted rates plug straight back into
    the model."""
    import jax

    from repro.core.stencil import get_stencil
    from repro.kernels.dispatch import (
        DispatchPolicy, kernel_op_features, select_kernel,
    )

    st = get_stencil(stencil)
    _, fused = select_kernel(st, max(steps_grid), DispatchPolicy(impl=impl))
    rng = np.random.default_rng(seed)
    out = []
    for h, w in bands:
        band = rng.standard_normal((h, w)).astype(np.float32)
        for steps in steps_grid:
            if h <= 2 * st.radius * steps:
                continue
            feats = kernel_op_features(impl, st, (h, w), steps,
                                       (False, True), (False, True), 4)
            if feats is None:
                continue
            mem_bytes, vpu_flops, mxu_flops = feats
            flops = mxu_flops if impl == "mxu" else vpu_flops
            t = _best_of(
                lambda: jax.block_until_ready(
                    fused(band, st.name, steps,
                          keep_top=False, keep_bottom=False)), iters)
            out.append((float(mem_bytes), float(flops), t))
    return out


def measure_codec(codec: str, sizes: Sequence[int], iters: int = 2,
                  seed: int = 0) -> List[Tuple[int, float, float]]:
    """Encode/decode wall clock per payload size for one registered
    transfer codec.  Returns ``(nbytes, t_encode, t_decode)``."""
    from repro.core.compress import get_codec

    c = get_codec(codec)
    rng = np.random.default_rng(seed)
    out = []
    for nbytes in sizes:
        rows = max(int(nbytes) // (4 * 256), 1)
        arr = rng.standard_normal((rows, 256)).astype(np.float32)
        # realistic stencil payloads are smooth-ish; zrle's win depends
        # on it, so bench on data with coherent rows
        arr = np.cumsum(arr, axis=1) * 1e-3
        t_enc = _best_of(lambda: c.encode(arr), iters)
        payload = c.encode(arr)
        t_dec = _best_of(
            lambda: c.decode(payload, arr.shape, arr.dtype), iters)
        out.append((arr.nbytes, t_enc, t_dec))
    return out


# ----------------------------------------------------------- the profile


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A versioned, persisted set of fitted model terms for one device.

    Everything is JSON-native so ``save``/``load`` round-trips
    bit-exactly.  ``hardware`` holds a complete
    :class:`~repro.core.analytic.Hardware` field dict — measured terms
    fitted, unmeasured ones inherited from ``base_hardware`` — so
    :meth:`as_hardware` is a drop-in anywhere the analytic model takes
    hardware constants.  ``kernel_terms`` and ``codec_throughput`` carry
    the per-impl / per-codec fits the tuner consumes on top."""

    profile_id: str
    fingerprint: Dict[str, object]
    hardware: Dict[str, object]
    kernel_terms: Dict[str, Dict[str, float]]
    codec_throughput: Dict[str, Dict[str, float]]
    residuals: Dict[str, float]
    created_at: str
    base_hardware: str
    schema_version: int = PROFILE_SCHEMA_VERSION

    def as_hardware(self) -> Hardware:
        return Hardware(**self.hardware)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2,
                          sort_keys=True) + "\n"

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "DeviceProfile":
        version = d.get("schema_version")
        if version != PROFILE_SCHEMA_VERSION:
            raise ProfileError(
                f"unsupported profile schema_version {version!r} "
                f"(this build reads {PROFILE_SCHEMA_VERSION})")
        fields = {f.name for f in dataclasses.fields(cls)}
        missing = fields - set(d)
        if missing:
            raise ProfileError(f"profile missing fields: {sorted(missing)}")
        return cls(**{k: v for k, v in d.items() if k in fields})

    @classmethod
    def load(cls, path: str) -> "DeviceProfile":
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ProfileError(f"cannot read profile {path!r}: {e}") from e
        if not isinstance(d, dict):
            raise ProfileError(f"profile {path!r} is not a JSON object")
        return cls.from_dict(d)


def resolve_hardware(hw: Union[Hardware, DeviceProfile, str, None],
                     default: Hardware = TPU_V5E) -> Hardware:
    """Coerce anything a ``hw=``/``profile=`` argument accepts into a
    :class:`Hardware`: an existing ``Hardware`` passes through, a
    :class:`DeviceProfile` contributes its fitted constants, a string is
    a profile path, ``None`` yields ``default``."""
    if hw is None:
        return default
    if isinstance(hw, Hardware):
        return hw
    if isinstance(hw, DeviceProfile):
        return hw.as_hardware()
    if isinstance(hw, str):
        return DeviceProfile.load(hw).as_hardware()
    raise TypeError(
        f"expected Hardware, DeviceProfile, profile path, or None; "
        f"got {type(hw).__name__}")


# -------------------------------------------------------- the harness

# microbenchmark ladders: quick mode stays CPU-CI-sized (a few seconds
# end to end), full mode adds rungs for tighter fits
_QUICK = dict(
    transfer_sizes=(1 << 20, 4 << 20, 16 << 20),
    dmem_sizes=(4 << 20, 16 << 20, 64 << 20),
    kernel_bands=((130, 258), (258, 258), (258, 514)),
    kernel_steps=(1, 2, 4),
    kernel_impls=("reference",),
    codec_sizes=(1 << 18, 1 << 20),
    codecs=("identity", "bf16", "zrle"),
    iters=2,
)
_FULL = dict(
    transfer_sizes=(1 << 20, 4 << 20, 16 << 20, 64 << 20, 256 << 20),
    dmem_sizes=(4 << 20, 16 << 20, 64 << 20, 256 << 20),
    kernel_bands=((130, 258), (258, 258), (258, 514), (514, 514)),
    kernel_steps=(1, 2, 4, 8),
    kernel_impls=("reference", "pallas", "pallas_db"),
    codec_sizes=(1 << 18, 1 << 20, 4 << 20),
    codecs=("identity", "bf16", "zrle"),
    iters=3,
)


def _utc_stamp() -> str:
    return time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())


def calibrate(quick: bool = True,
              base_hw: Hardware = TPU_V5E,
              stencil: str = "box2d1r",
              kernel_impls: Optional[Iterable[str]] = None,
              seed: int = 0,
              progress=None) -> DeviceProfile:
    """Run the microbenchmark suite on the current backend and fit a
    :class:`DeviceProfile`.

    ``quick`` trims the size ladders to CPU-CI scale.  ``base_hw``
    donates the constants no microbenchmark here can measure (memory
    capacities, MXU peak, ICI bandwidth); everything the Sec. III model
    actually prices transfers and kernels with — ``bw_intc``,
    ``bw_dmem``, ``peak_vpu_flops``, ``t_ici_latency`` — is fitted.
    ``progress`` (callable taking one string) narrates long runs."""
    cfg = dict(_QUICK if quick else _FULL)
    if kernel_impls is not None:
        cfg["kernel_impls"] = tuple(kernel_impls)
    say = progress or (lambda msg: None)
    residuals: Dict[str, float] = {}

    say("measuring interconnect")
    xfer = measure_interconnect(cfg["transfer_sizes"], cfg["iters"], seed)
    nbytes = [s[0] for s in xfer]
    lat_h2d, bw_h2d, r_h2d = fit_affine(nbytes, [s[1] for s in xfer])
    lat_d2h, bw_d2h, r_d2h = fit_affine(nbytes, [s[2] for s in xfer])
    bw_intc = max(min(bw_h2d, bw_d2h), _MIN_RATE)
    # the launch-latency intercept doubles as the collective-phase
    # latency proxy: one small-message round trip is what a halo
    # exchange pays before bytes flow
    t_lat = max(lat_h2d, lat_d2h, 0.0)
    residuals["interconnect_h2d"] = r_h2d
    residuals["interconnect_d2h"] = r_d2h

    say("measuring off-chip memory")
    dmem = measure_dmem(cfg["dmem_sizes"], cfg["iters"], seed)
    _, bw_stream, r_dmem = fit_affine(
        [2 * s[0] for s in dmem], [s[1] for s in dmem])
    bw_dmem = max(bw_stream, _MIN_RATE)
    residuals["dmem"] = r_dmem

    kernel_terms: Dict[str, Dict[str, float]] = {}
    peak_vpu = base_hw.peak_vpu_flops
    for impl in cfg["kernel_impls"]:
        say(f"measuring kernel impl {impl!r}")
        pts = measure_kernel_impl(impl, stencil, cfg["kernel_bands"],
                                  cfg["kernel_steps"], cfg["iters"], seed)
        if not pts:
            continue
        bw_eff, flops_eff, resid = fit_two_term(
            [p[0] for p in pts], [p[1] for p in pts], [p[2] for p in pts])
        kernel_terms[impl] = {
            "bw_eff": max(bw_eff, _MIN_RATE),
            "flops_eff": max(flops_eff, _MIN_RATE),
            "residual": resid,
            "n_points": len(pts),
        }
        residuals[f"kernel_{impl}"] = resid
    if "reference" in kernel_terms:
        # the oracle path's fitted FLOP rate is the best available
        # backend-wide VPU estimate for the generic roofline terms
        peak_vpu = kernel_terms["reference"]["flops_eff"]

    codec_tp: Dict[str, Dict[str, float]] = {}
    for codec in cfg["codecs"]:
        say(f"measuring codec {codec!r}")
        pts = measure_codec(codec, cfg["codec_sizes"], cfg["iters"], seed)
        nb = [p[0] for p in pts]
        _, enc_bps, r_enc = fit_affine(nb, [p[1] for p in pts])
        _, dec_bps, r_dec = fit_affine(nb, [p[2] for p in pts])
        resid = max(r_enc, r_dec)
        codec_tp[codec] = {
            "encode_bps": max(enc_bps, _MIN_RATE),
            "decode_bps": max(dec_bps, _MIN_RATE),
            "residual": resid,
        }
        residuals[f"codec_{codec}"] = resid

    fp = backend_fingerprint()
    hw = dataclasses.replace(
        base_hw,
        name=f"calibrated-{fp['backend']}",
        bw_intc=bw_intc,
        bw_dmem=bw_dmem,
        peak_vpu_flops=max(peak_vpu, _MIN_RATE),
        t_ici_latency=t_lat,
    )
    digest = hashlib.sha256(
        json.dumps(fp, sort_keys=True).encode()).hexdigest()[:10]
    return DeviceProfile(
        profile_id=f"{fp['backend']}-{digest}",
        fingerprint=fp,
        hardware=dataclasses.asdict(hw),
        kernel_terms=kernel_terms,
        codec_throughput=codec_tp,
        residuals=residuals,
        created_at=_utc_stamp(),
        base_hardware=base_hw.name,
    )
