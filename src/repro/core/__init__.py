"""SO2DR core: the paper's contribution in JAX.

Engines (oocore), oracle (reference), stencil registry, chunk algebra
(tiling), Sec. III/IV-C models (analytic/params), geometry-only stats
(accounting), and the L2 distributed engine (distributed).
"""
from .analytic import EngineTimes, Hardware, RTX3080_PAPER, TPU_V5E, model_times  # noqa: F401
from .oocore import InCore, NaiveTB, ResReu, SO2DR, TransferStats, get_engine  # noqa: F401
from .reference import multi_step_band, run_reference, step_band, step_domain  # noqa: F401
from .stencil import PAPER_BENCHMARKS, REGISTRY, Stencil, get_stencil  # noqa: F401
