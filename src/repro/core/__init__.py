"""SO2DR core: the paper's contribution in JAX.

Engine planners (oocore) compile to transfer/kernel op schedules (plan),
lowered to slot-bound stage programs with a shape-bucketed kernel cache
(lower), interpreted by pluggable executors (executor: eager /
double-buffered / dry-run).  Oracle (reference), stencil registry, chunk algebra (tiling),
Sec. III/IV-C models (analytic/params), plan-derived stats (accounting).
The L2 sharded planner (shard) compiles per-device op streams with
halo-exchange ops, executed by the single-device lockstep simulator or
the shard_map/ppermute backend (distributed); when a shard's working
set exceeds device capacity, the hierarchical compiler (hierarchy)
nests an L1 out-of-core streaming plan inside every shard.
"""
from .analytic import EngineTimes, Hardware, RTX3080_PAPER, TPU_V5E, model_times, times_from_plan  # noqa: F401
from .autotune import BoxChoice, Choice, ShardedChoice, autotune, autotune_box, autotune_sharded  # noqa: F401
from .autotune import optimization_target, predicted_makespan, stage_costs, trapezoid_redundant_elements  # noqa: F401
from .calibrate import DeviceProfile, ProfileError, calibrate, resolve_hardware  # noqa: F401
from .compress import CODECS, Codec, compress_plan, get_codec, register_codec  # noqa: F401
from .executor import DoubleBufferedExecutor, DryRunExecutor, EagerExecutor, get_executor  # noqa: F401
from .executor import ShardMapExecutor, ShardedSimExecutor  # noqa: F401
from .faults import FAULT_KINDS, FaultInjector, FaultPlan, FaultTrigger, InjectedFault, RetryPolicy  # noqa: F401
from .faults import KernelFault, RankLossFault, SlotExhaustedError, TransientTransferError  # noqa: F401
from .hierarchy import HierarchicalPlan, compile_hierarchical  # noqa: F401
from .lower import CompiledPlan, CompiledShardedPlan, ExecStats, KernelCache, lower, lower_sharded  # noqa: F401
from .oocore import BoxTB, InCore, NaiveTB, ResReu, SO2DR, TransferStats, get_engine  # noqa: F401
from .oocore import compile_box_plan, compile_plan, compile_plan_nd  # noqa: F401
from .plan import Box, BufferRead, BufferWrite, Compress, D2H, Decompress, ExecutionPlan, FusedKernel, H2D, HostCommit  # noqa: F401
from .plan import DeviceShard, HaloRecv, HaloSend, ShardKernel, ShardLoad, ShardStore, ShardedPlan  # noqa: F401
from .recovery import PlanCheckpointer, PlanExecutionError, plan_fingerprint, resume_plan, run_with_recovery  # noqa: F401
from .reference import multi_step_band, multi_step_box, run_reference, step_band, step_band_nd, step_domain  # noqa: F401
from .shard import compile_sharded, ghost_wedge_elements  # noqa: F401
from .stencil import PAPER_BENCHMARKS, REGISTRY, Stencil, get_stencil  # noqa: F401
from .tune import TuneResult, TuneSpec, tune  # noqa: F401
