"""Run-time parameter selection heuristic (paper Sec. IV-C).

Given the stencil code (radius, element size, arrays, domain) and the
hardware, enumerate feasible ``(d, S_TB)`` combinations that

* keep the kernel phase dominant over transfer (the paper's "satisfy"
  inequality) so that on-chip reuse — not the interconnect — decides
  performance,
* fit ``N_strm`` in-flight working sets in device memory,
* keep the halo working space within one chunk (region-sharing feasibility),
* keep more chunks than streams (no idle streams).

The heuristic reduces the search space; like the paper, callers then sweep
the survivors (benchmarks/fig5_config_sweep.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

from .analytic import Hardware

__all__ = ["CodeSpec", "Candidate", "feasible", "enumerate_candidates"]


@dataclasses.dataclass(frozen=True)
class CodeSpec:
    """Run-time configuration variables (paper Table I)."""

    sz: int                # size along each dimension
    radius: int            # stencil radius r
    dim: int = 2
    n_arrays: int = 1      # N_a
    b_elem: int = 4        # bytes per element
    total_steps: int = 640  # S_tot

    @property
    def row_elems(self) -> int:
        """Elements per row incl. the 2r frame: (sz + 2r)^(dim-1)."""
        return (self.sz + 2 * self.radius) ** (self.dim - 1)

    def d_chk(self, d: int) -> float:
        """Chunk size in elements: sz * (sz+2r)^(dim-1) / d."""
        return self.sz * self.row_elems / d

    @property
    def w_halo(self) -> float:
        """Halo working-space per TB step: 2r * (sz+2r)^(dim-1) elements."""
        return 2 * self.radius * self.row_elems


@dataclasses.dataclass(frozen=True)
class Candidate:
    d: int
    s_tb: int
    working_set_bytes: int
    halo_fraction: float   # halo working space / chunk (paper: keep < ~20%)


def feasible(code: CodeSpec, hw: Hardware, d: int, s_tb: int) -> bool:
    d_chk = code.d_chk(d)
    w_tb = code.w_halo * s_tb
    b = code.b_elem
    # satisfy: kernel time (off-chip bound, n_a arrays) > transfer time
    satisfy = (d_chk + w_tb) * code.n_arrays * b / hw.bw_dmem * s_tb > (
        d_chk * max(code.n_arrays - 1, 1) * b / hw.bw_intc
    )
    fits = (d_chk + w_tb) * hw.n_streams * b <= hw.c_dmem
    halo_ok = w_tb <= d_chk
    streams_ok = d > hw.n_streams
    return bool(satisfy and fits and halo_ok and streams_ok)


def enumerate_candidates(
    code: CodeSpec,
    hw: Hardware,
    d_grid: Iterable[int] = (4, 8, 16, 32),
    s_tb_grid: Iterable[int] = (40, 80, 160, 320, 640),
) -> List[Candidate]:
    out: List[Candidate] = []
    for d in d_grid:
        for s_tb in s_tb_grid:
            if s_tb > code.total_steps:
                continue
            if feasible(code, hw, d, s_tb):
                d_chk = code.d_chk(d)
                w_tb = code.w_halo * s_tb
                out.append(
                    Candidate(
                        d=d,
                        s_tb=s_tb,
                        working_set_bytes=int((d_chk + w_tb) * code.b_elem),
                        halo_fraction=w_tb / d_chk,
                    )
                )
    return out
