"""Stencil definitions.

A :class:`Stencil` is the computing template of the paper (Sec. II-A): every
interior element is updated from its neighbours within ``radius``.  The
registry mirrors the paper's benchmark suite (Table III):

* ``box2d{1,2,3,4}r`` — box-type, ``(2x+1)**2`` points, arithmetic intensity
  ``2*(2x+1)**2 - 1`` FLOPs/element,
* ``gradient2d``      — star-type, 5 points, 19 FLOPs/element (nonlinear),
* ``star2d{1..4}r``   — star-type axis-only stencils (extra, used in tests).

All stencils use the *interior-update* convention: an ``r``-wide Dirichlet
frame around the domain is held constant; only interior elements are updated.
The oracle in :mod:`repro.core.reference` and every out-of-core engine in
:mod:`repro.core.oocore` share this convention.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

__all__ = ["Stencil", "get_stencil", "REGISTRY", "box_coeffs"]


def box_coeffs(radius: int) -> np.ndarray:
    """Deterministic, non-separable, sum-to-one box coefficients.

    Distinct per-tap weights rule out accidental separable shortcuts in
    optimized kernels while keeping iterates bounded (weights sum to 1).
    """
    n = 2 * radius + 1
    iy, ix = np.mgrid[0:n, 0:n]
    w = 1.0 + 0.1 * iy + 0.01 * ix + 0.003 * iy * ix  # non-separable
    return (w / w.sum()).astype(np.float64)


def star_coeffs(radius: int) -> np.ndarray:
    """Axis-only (star) coefficients embedded in a (2r+1)x(2r+1) grid."""
    n = 2 * radius + 1
    c = np.zeros((n, n))
    for k in range(1, radius + 1):
        c[radius + k, radius] = c[radius - k, radius] = 0.35 / (2 * k * radius)
        c[radius, radius + k] = c[radius, radius - k] = 0.4 / (2 * k * radius)
    c[radius, radius] = 1.0 - c.sum()
    return c


@dataclasses.dataclass(frozen=True)
class Stencil:
    """An N-D stencil template (``ndim`` trailing spatial axes).

    ``step_valid`` maps an array to its "valid" region — every spatial
    extent shrinks by ``2r`` — the kernel-level primitive everything else
    is built from.
    """

    name: str
    radius: int
    kind: str                    # "box" | "star" | "gradient" | "heat"
    flops_per_elem: int          # arithmetic intensity (paper Table III)
    points: int                  # taps read per output element
    _step_valid: Callable[[jnp.ndarray], jnp.ndarray]
    coeffs: np.ndarray | None = None   # (2r+1, 2r+1) for linear 2-D stencils
    ndim: int = 2                # spatial rank of the template

    def step_valid(self, x: jnp.ndarray) -> jnp.ndarray:
        """One time step on the valid interior: every spatial extent
        shrinks by ``2r`` (e.g. ``(H, W) -> (H-2r, W-2r)``)."""
        return self._step_valid(x)

    @property
    def is_linear(self) -> bool:
        return self.coeffs is not None


def _linear_step(coeffs: np.ndarray) -> Callable[[jnp.ndarray], jnp.ndarray]:
    n = coeffs.shape[0]
    taps = [
        (dy, dx, float(coeffs[dy, dx]))
        for dy in range(n)
        for dx in range(n)
        if coeffs[dy, dx] != 0.0
    ]

    def step(x: jnp.ndarray) -> jnp.ndarray:
        h, w = x.shape[-2], x.shape[-1]
        acc = None
        for dy, dx, c in taps:
            sl = x[..., dy : h - (n - 1) + dy, dx : w - (n - 1) + dx]
            term = jnp.asarray(c, x.dtype) * sl
            acc = term if acc is None else acc + term
        return acc

    return step


def _gradient_step(x: jnp.ndarray) -> jnp.ndarray:
    """5-point nonlinear gradient stencil (19 FLOPs/element).

    c + dt * (gn+gs+gw+ge) / sqrt(eps + gn^2+gs^2+gw^2+ge^2)  with
    g* the one-sided differences — an anisotropic-diffusion style update.
    """
    c = x[..., 1:-1, 1:-1]
    gn = x[..., :-2, 1:-1] - c
    gs = x[..., 2:, 1:-1] - c
    gw = x[..., 1:-1, :-2] - c
    ge = x[..., 1:-1, 2:] - c
    num = gn + gs + gw + ge
    den = gn * gn + gs * gs + gw * gw + ge * ge
    eps = jnp.asarray(1e-3, x.dtype)
    dt = jnp.asarray(0.1, x.dtype)
    return c + dt * num * jax_rsqrt(den + eps)


def jax_rsqrt(v: jnp.ndarray) -> jnp.ndarray:
    import jax

    return jax.lax.rsqrt(v)


def _make_box(radius: int) -> Stencil:
    c = box_coeffs(radius)
    pts = (2 * radius + 1) ** 2
    return Stencil(
        name=f"box2d{radius}r",
        radius=radius,
        kind="box",
        flops_per_elem=2 * pts - 1,
        points=pts,
        _step_valid=_linear_step(c),
        coeffs=c,
    )


def _make_star(radius: int) -> Stencil:
    c = star_coeffs(radius)
    pts = 4 * radius + 1
    return Stencil(
        name=f"star2d{radius}r",
        radius=radius,
        kind="star",
        flops_per_elem=2 * pts - 1,
        points=pts,
        _step_valid=_linear_step(c),
        coeffs=c,
    )


def _heat3d_step(x: jnp.ndarray) -> jnp.ndarray:
    """3-D 7-point heat (star) stencil: explicit Euler Laplacian update.

    ``c + dt * (sum of 6 face neighbours - 6c)`` with ``dt = 0.1`` —
    weights sum to 1 and stay non-negative, so iterates remain bounded.
    """
    c = x[..., 1:-1, 1:-1, 1:-1]
    lap = (
        x[..., :-2, 1:-1, 1:-1] + x[..., 2:, 1:-1, 1:-1]
        + x[..., 1:-1, :-2, 1:-1] + x[..., 1:-1, 2:, 1:-1]
        + x[..., 1:-1, 1:-1, :-2] + x[..., 1:-1, 1:-1, 2:]
    )
    dt = jnp.asarray(0.1, x.dtype)
    six = jnp.asarray(6.0, x.dtype)
    return c + dt * (lap - six * c)


REGISTRY: Dict[str, Stencil] = {}
for _r in (1, 2, 3, 4):
    REGISTRY[f"box2d{_r}r"] = _make_box(_r)
    REGISTRY[f"star2d{_r}r"] = _make_star(_r)
REGISTRY["heat3d1r"] = Stencil(
    name="heat3d1r",
    radius=1,
    kind="heat",
    flops_per_elem=13,
    points=7,
    _step_valid=_heat3d_step,
    coeffs=None,
    ndim=3,
)
REGISTRY["gradient2d"] = Stencil(
    name="gradient2d",
    radius=1,
    kind="gradient",
    flops_per_elem=19,
    points=5,
    _step_valid=_gradient_step,
    coeffs=None,
)

PAPER_BENCHMARKS = ("box2d1r", "box2d2r", "box2d3r", "box2d4r", "gradient2d")


def get_stencil(name: str) -> Stencil:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown stencil {name!r}; known: {sorted(REGISTRY)}")
