"""Transfer codecs + the compression rewrite pass over the plan IR.

On-the-fly CPU-GPU transfer compression for out-of-core stencils
(Shen et al., arXiv 2109.05410 / 2204.11315): the remaining H2D/D2H
traffic after SO2DR's region sharing is itself compressible, and hiding
the codec work behind kernel execution turns the saved wire bytes into
wall-clock time.  This module keeps the two halves of that idea apart:

* **exact encode/decode pairs** — every codec round-trips real bytes.
  Lossless codecs (``identity``, ``zrle``) reproduce the input bit for
  bit, including negative zeros, infinities, and NaN payloads; the lossy
  ``bf16`` codec guarantees a per-element relative error bound
  (:attr:`Codec.max_rel_error`).
* **an analytic ratio model** — :meth:`Codec.wire_nbytes` maps a raw
  byte count to the modeled on-the-wire byte count *deterministically at
  plan time*, so compressed schedules are costed by the same dry-run
  executor as uncompressed ones and accounting stays a property of the
  plan.  For shape-driven codecs (``identity``, ``bf16``) the model is
  exact; for the data-dependent ``zrle`` it is the tuned halo-band
  estimate documented on the class (the measured payload of a concrete
  array is ``codec.encode(arr).nbytes``).

:func:`compress_plan` is the rewrite pass: it wraps every ``H2D``/``D2H``
of a compiled :class:`~repro.core.plan.ExecutionPlan` in a
``Compress``/``Decompress`` pair carrying the codec id and the raw/wire
byte counts — no planner changes, any engine's schedule compresses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import numpy as np

from .plan import (
    D2H, H2D, Compress, Decompress, ExecutionPlan, HaloCompress,
    HaloDecompress, HaloRecv, HaloSend, Op, ShardOp, ShardedPlan,
)

__all__ = [
    "Codec",
    "IdentityCodec",
    "Bf16Codec",
    "ZrleCodec",
    "CODECS",
    "register_codec",
    "get_codec",
    "compress_plan",
]


class Codec:
    """One transfer codec: an exact encode/decode pair + a ratio model."""

    name: str = "base"
    lossless: bool = True
    # per-element relative error bound of one encode/decode round trip
    # (0.0 for lossless codecs)
    max_rel_error: float = 0.0
    # element sizes the encode/decode pair can handle (None = any);
    # compress_plan rejects incompatible plans at rewrite time so the
    # dry-run/autotune path can never cost a codec that would crash at
    # execution time
    itemsizes: Optional[Tuple[int, ...]] = None

    def encode(self, arr: np.ndarray) -> np.ndarray:
        """Encode an array into a 1-D ``uint8`` wire payload."""
        raise NotImplementedError

    def decode(self, payload: np.ndarray, shape: Tuple[int, ...], dtype) -> np.ndarray:
        """Decode a wire payload back into an array of ``shape``/``dtype``."""
        raise NotImplementedError

    def wire_nbytes(self, raw_nbytes: int, itemsize: int) -> int:
        """Modeled wire bytes for a ``raw_nbytes`` transfer (plan-time
        deterministic — must not depend on array values)."""
        raise NotImplementedError


class IdentityCodec(Codec):
    """No-op codec: wire bytes equal raw bytes (the uncompressed baseline,
    kept in the registry so sweeps and CI gates treat "no compression" as
    just another codec choice)."""

    name = "identity"
    lossless = True

    def encode(self, arr: np.ndarray) -> np.ndarray:
        return np.ascontiguousarray(arr).view(np.uint8).reshape(-1)

    def decode(self, payload: np.ndarray, shape: Tuple[int, ...], dtype) -> np.ndarray:
        return payload.view(dtype).reshape(shape).copy()

    def wire_nbytes(self, raw_nbytes: int, itemsize: int) -> int:
        return raw_nbytes


class Bf16Codec(Codec):
    """fp32 -> bf16 truncation with round-to-nearest-even.

    Keeps the sign, the full 8-bit exponent, and the top 7 mantissa bits
    of every fp32 word: exactly half the wire bytes, with a relative
    error bound of 2**-8 per round trip (one ulp of the 8-bit effective
    mantissa, nearest rounding).  The bound holds for normal values whose
    rounded magnitude stays finite — exactly like standard bf16
    conversion, magnitudes above the bf16 max (~3.39e38) round to inf
    and fp32 denormals (< 2**-126) flush toward zero.  NaN payloads
    survive (the rounding bias never clears an exponent); the decode
    zero-fills the dropped mantissa bits, so re-encoding a decoded array
    is lossless (idempotent across NaiveTB's repeated halo round
    trips)."""

    name = "bf16"
    lossless = False
    itemsizes = (4,)
    max_rel_error = 2.0**-8  # for normal, in-bf16-range values (see docstring)

    def encode(self, arr: np.ndarray) -> np.ndarray:
        if arr.dtype != np.float32:
            raise TypeError(f"bf16 codec expects float32, got {arr.dtype}")
        u = np.ascontiguousarray(arr).view(np.uint32)
        # round to nearest even on the dropped 16 bits; keep NaNs quiet
        bias = np.uint32(0x7FFF) + ((u >> np.uint32(16)) & np.uint32(1))
        nan = np.isnan(arr)
        hi = np.where(nan, u >> np.uint32(16), (u + bias) >> np.uint32(16))
        return hi.astype(np.uint16).view(np.uint8).reshape(-1)

    def decode(self, payload: np.ndarray, shape: Tuple[int, ...], dtype) -> np.ndarray:
        if np.dtype(dtype) != np.float32:
            raise TypeError(f"bf16 codec expects float32, got {dtype}")
        hi = payload.view(np.uint16).astype(np.uint32)
        return (hi << np.uint32(16)).view(np.float32).reshape(shape).copy()

    def wire_nbytes(self, raw_nbytes: int, itemsize: int) -> int:
        return raw_nbytes // 2


class ZrleCodec(Codec):
    """Row-delta + zero-word run suppression, tuned for stencil halo bands.

    Encode = XOR every row with its predecessor (halo bands are smooth
    along the streaming axis, so consecutive rows share sign/exponent/
    high-mantissa bits and the deltas are full of zero words), then pack
    the flattened delta words as 8-word groups with a presence bitmask:
    one mask byte plus only the nonzero words of each group.  Pure bit
    arithmetic on the ``uint32`` views — exact for every fp32 bit
    pattern, -0.0 and NaN payloads included.

    Wire model: one mask byte per 8 words plus a ``ZERO_WORD_FRACTION``
    of the words suppressed — the plan-time estimate for halo-band
    traffic (the measured payload of a concrete array is
    ``encode(arr).nbytes``), clamped to the raw size so degenerate few-
    word transfers never model as expansion."""

    name = "zrle"
    lossless = True
    itemsizes = (4,)
    # modeled fraction of delta words that are exactly zero on halo bands
    ZERO_WORD_FRACTION = 3.0 / 8.0

    def encode(self, arr: np.ndarray) -> np.ndarray:
        if arr.dtype.itemsize != 4:
            raise TypeError(f"zrle codec expects 4-byte elements, got {arr.dtype}")
        words = np.ascontiguousarray(arr).view(np.uint32)
        if words.ndim >= 2:
            delta = words.copy()
            delta[1:] ^= words[:-1]
        else:
            delta = words
        flat = delta.reshape(-1)
        pad = (-flat.size) % 8
        if pad:
            flat = np.concatenate([flat, np.zeros(pad, np.uint32)])
        groups = flat.reshape(-1, 8)
        nonzero = groups != 0
        masks = np.packbits(nonzero, axis=1, bitorder="little").reshape(-1)
        literals = groups[nonzero].view(np.uint8)
        return np.concatenate([masks.view(np.uint8), literals.reshape(-1)])

    def decode(self, payload: np.ndarray, shape: Tuple[int, ...], dtype) -> np.ndarray:
        nwords = int(np.prod(shape, dtype=np.int64))
        ngroups = -(-nwords // 8)
        masks = payload[:ngroups]
        nonzero = np.unpackbits(masks, bitorder="little").astype(bool)
        literal_bytes = payload[ngroups:]
        flat = np.zeros(ngroups * 8, np.uint32)
        flat[nonzero] = literal_bytes.view(np.uint32)
        delta = flat[:nwords].reshape(shape)
        if delta.ndim >= 2:
            words = np.bitwise_xor.accumulate(delta, axis=0, dtype=np.uint32)
        else:
            words = delta
        return words.view(dtype).reshape(shape).copy()

    def wire_nbytes(self, raw_nbytes: int, itemsize: int) -> int:
        nwords = raw_nbytes // 4
        masks = -(-nwords // 8)
        literals = nwords - int(nwords * self.ZERO_WORD_FRACTION)
        return min(raw_nbytes, masks + 4 * literals)


CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Add a codec instance to the registry (name collisions are bugs)."""
    if codec.name in CODECS:
        raise ValueError(f"codec {codec.name!r} already registered")
    CODECS[codec.name] = codec
    return codec


for _codec in (IdentityCodec(), Bf16Codec(), ZrleCodec()):
    register_codec(_codec)


def get_codec(codec: Union[str, Codec]) -> Codec:
    if isinstance(codec, Codec):
        return codec
    try:
        return CODECS[codec]
    except KeyError:
        raise KeyError(f"unknown codec {codec!r}; known: {sorted(CODECS)}")


def compress_plan(plan, codec: Union[str, Codec]):
    """Rewrite a compiled plan so every transfer goes through ``codec``.

    For an :class:`~repro.core.plan.ExecutionPlan` each ``H2D``/``D2H``
    is wrapped in a ``Compress``/``Decompress`` pair that carries the
    codec id, the raw byte count, and the modeled wire byte count; the
    wrapped transfer op itself is untouched (its row provenance and raw
    ``nbytes`` stay authoritative).  Everything else — kernels, buffer
    traffic, commit barriers, op order — is preserved, so executors that
    ignore the codec ops would still compute the same result.

    For a :class:`~repro.core.plan.ShardedPlan` the pass learns the
    collective vocabulary instead: every ``HaloSend`` gains a
    ``HaloCompress`` before it, every real ``HaloRecv`` a
    ``HaloDecompress`` after it (mesh-edge zero fills are never
    wrapped), so ``ici_wire_bytes`` diverges from ``ici_bytes`` exactly
    like the H2D wire accounting does — the ICI link is just another
    interconnect to the codec registry (arXiv 2204.11315 applied one
    level up).  A :class:`~repro.core.hierarchy.HierarchicalPlan`
    compresses its outer sharded plan (inner streams take their own
    codec at :func:`~repro.core.hierarchy.compile_hierarchical` time)."""
    if isinstance(plan, ShardedPlan):
        return _compress_sharded(plan, codec)
    if not isinstance(plan, ExecutionPlan) and hasattr(plan, "outer"):
        # HierarchicalPlan (duck-typed: avoids a hierarchy import cycle)
        return dataclasses.replace(
            plan, outer=_compress_sharded(plan.outer, codec))
    if plan.codec:
        raise ValueError(
            f"plan is already compressed with {plan.codec!r}; nesting "
            f"codecs would double-count wire bytes (rewrite the base plan)")
    c = get_codec(codec)
    if c.itemsizes is not None and plan.itemsize not in c.itemsizes:
        raise ValueError(
            f"codec {c.name!r} supports itemsize(s) {c.itemsizes}, but the "
            f"plan has itemsize {plan.itemsize}")
    ops: list[Op] = []
    for op in plan.ops:
        if isinstance(op, (H2D, D2H)):
            direction = "h2d" if isinstance(op, H2D) else "d2h"
            meta = dict(
                codec=c.name,
                reg=op.reg,
                direction=direction,
                raw_nbytes=op.nbytes,
                wire_nbytes=c.wire_nbytes(op.nbytes, plan.itemsize),
                box=op.box,
                round=op.round,
                chunk=op.chunk,
            )
            ops.extend([Compress(**meta), op, Decompress(**meta)])
        else:
            ops.append(op)
    return dataclasses.replace(plan, ops=tuple(ops), codec=c.name)


def _compress_sharded(plan: ShardedPlan,
                      codec: Union[str, Codec]) -> ShardedPlan:
    """The :func:`compress_plan` rewrite over a sharded plan's streams."""
    if plan.codec:
        raise ValueError(
            f"plan is already compressed with {plan.codec!r}; nesting "
            f"codecs would double-count wire bytes (rewrite the base plan)")
    c = get_codec(codec)
    if c.itemsizes is not None and plan.itemsize not in c.itemsizes:
        raise ValueError(
            f"codec {c.name!r} supports itemsize(s) {c.itemsizes}, but the "
            f"plan has itemsize {plan.itemsize}")
    streams: list[Tuple[ShardOp, ...]] = []
    for stream in plan.streams:
        ops: list[ShardOp] = []
        for op in stream:
            if isinstance(op, HaloSend):
                meta = dict(
                    codec=c.name, rank=op.rank, peer=op.dst, axis=op.axis,
                    side=op.side, direction="send", raw_nbytes=op.nbytes,
                    wire_nbytes=c.wire_nbytes(op.nbytes, plan.itemsize),
                    round=op.round, phase=op.phase,
                )
                ops.extend([HaloCompress(**meta), op])
            elif isinstance(op, HaloRecv) and op.src >= 0:
                meta = dict(
                    codec=c.name, rank=op.rank, peer=op.src, axis=op.axis,
                    side=op.side, direction="recv", raw_nbytes=op.nbytes,
                    wire_nbytes=c.wire_nbytes(op.nbytes, plan.itemsize),
                    round=op.round, phase=op.phase,
                )
                ops.extend([op, HaloDecompress(**meta)])
            else:
                ops.append(op)
        streams.append(tuple(ops))
    return dataclasses.replace(plan, streams=tuple(streams), codec=c.name)
