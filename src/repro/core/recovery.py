"""Recovery: typed execution failure, HostCommit checkpoint/resume.

``HostCommit`` is the only ordering barrier an executor must respect
(:class:`repro.core.plan.HostCommit`), which makes round boundaries
exact, bit-reproducible recovery points: after round ``r`` commits, the
host array *is* the complete machine state — registers and buffers
never cross a barrier.  This module turns that property into a
fault-tolerance API:

* :class:`PlanExecutionError` — what a terminal
  :class:`~repro.core.faults.InjectedFault` (or a real device abort)
  surfaces as, carrying the last committed round and the plan
  fingerprint so a supervisor knows exactly where to resume.
* :func:`plan_fingerprint` — a stable digest of a plan's full geometry
  and op stream; a checkpoint taken under one fingerprint is never
  resumed into a different plan.
* :func:`resume_plan` — compiles a continuation plan of the rounds at
  or after ``from_round`` by filtering the op stream (every op carries
  its round; registers/buffers are intra-round, so the suffix is a
  well-formed plan).
* :class:`PlanCheckpointer` — the per-round commit hook: snapshots
  ``(host array, round index, plan fingerprint)`` through
  :class:`repro.checkpoint.manager.CheckpointManager` every ``every``
  rounds (the cadence knob).
* :func:`run_with_recovery` — the supervisor loop: execute; on a
  terminal fault restore the newest matching checkpoint, resume from
  the following round, repeat.  Crash at *any* round → resume →
  bit-identical to the uninterrupted run, for every engine × executor
  × codec (property-tested in ``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import numpy as np

from .faults import FaultInjector, FaultPlan, RetryPolicy
from .plan import ExecutionPlan, FusedKernel, HostCommit

__all__ = [
    "PlanExecutionError", "plan_fingerprint", "resume_plan",
    "PlanCheckpointer", "run_with_recovery", "RetryPolicy",
]


class PlanExecutionError(RuntimeError):
    """Terminal execution failure with an exact recovery point.

    ``last_committed_round`` is the newest round whose ``HostCommit``
    barrier fully drained before the failure (``-1`` when nothing
    committed); resuming from ``last_committed_round + 1`` on the
    committed host state reproduces the uninterrupted run bitwise."""

    def __init__(self, message: str, fault: Optional[BaseException] = None,
                 last_committed_round: int = -1, fingerprint: str = ""):
        super().__init__(message)
        self.fault = fault
        self.last_committed_round = last_committed_round
        self.fingerprint = fingerprint

    @property
    def next_round(self) -> int:
        return self.last_committed_round + 1


def plan_fingerprint(plan) -> str:
    """Stable content digest of a plan (works for both
    :class:`~repro.core.plan.ExecutionPlan` and
    :class:`~repro.core.plan.ShardedPlan`): every field of every op is a
    plain value, so the dataclass repr is deterministic across
    processes."""
    return hashlib.sha256(repr(plan).encode()).hexdigest()[:16]


def _round_steps(plan: ExecutionPlan) -> dict:
    """Time steps advanced per round, read off the op stream: for one
    representative chunk of each round, the FusedKernel ``steps`` sum to
    the round's step count (uniform across the round's chunks)."""
    rep_chunk: dict = {}
    for op in plan.ops:
        if isinstance(op, FusedKernel):
            c = rep_chunk.get(op.round)
            if c is None or op.chunk < c:
                rep_chunk[op.round] = op.chunk
    steps: dict = {}
    for op in plan.ops:
        if isinstance(op, FusedKernel) and op.chunk == rep_chunk[op.round]:
            steps[op.round] = steps.get(op.round, 0) + op.steps
    return steps


def resume_plan(plan: ExecutionPlan, from_round: int) -> ExecutionPlan:
    """The continuation plan: all ops of rounds ``>= from_round``.

    Valid because registers and buffers never cross a ``HostCommit``
    barrier — a round's op group is self-contained given the committed
    host state.  ``exact_elements`` is rescaled to the remaining time
    steps so redundancy accounting stays honest on the continuation."""
    if from_round <= 0:
        return plan
    ops = tuple(op for op in plan.ops if op.round >= from_round)
    steps = _round_steps(plan)
    remaining = sum(v for r, v in steps.items() if r >= from_round)
    per_step = plan.exact_elements // plan.n if plan.n else 0
    return dataclasses.replace(plan, ops=ops,
                               exact_elements=per_step * remaining)


class PlanCheckpointer:
    """The per-round commit hook: every ``every`` rounds, snapshot the
    committed host array + round index + plan fingerprint through a
    :class:`~repro.checkpoint.manager.CheckpointManager`.

    Pass :attr:`on_commit` to an executor's ``execute`` (or to
    :func:`run_with_recovery`, which wires it for you); ``every`` is the
    cadence knob — a resume after a skipped round just recomputes from
    the newest snapshot, correctness is cadence-independent."""

    def __init__(self, manager, plan, every: int = 1):
        if every < 1:
            raise ValueError(f"checkpoint cadence every={every} must be >= 1")
        self.manager = manager
        self.fingerprint = plan_fingerprint(plan)
        self.every = every
        self.saves = 0

    def on_commit(self, rnd: int, host: np.ndarray) -> None:
        if rnd % self.every:
            return
        self.manager.save(rnd, {"host": host},
                          extra_meta={"round": rnd,
                                      "plan_fingerprint": self.fingerprint})
        self.saves += 1

    def latest(self) -> Optional[Tuple[int, np.ndarray]]:
        """Newest snapshot taken under this plan's fingerprint, as
        ``(round, host)`` — ``None`` when nothing matching exists."""
        for step in reversed(self.manager.all_steps()):
            tree, meta = self.manager.restore({"host": None}, step)
            if meta.get("plan_fingerprint") == self.fingerprint:
                return int(meta["round"]), tree["host"]
        return None


def run_with_recovery(plan: ExecutionPlan, x: np.ndarray, executor=None,
                      faults: Optional[FaultPlan] = None,
                      retry: Optional[RetryPolicy] = None,
                      checkpoint: Optional[PlanCheckpointer] = None,
                      max_resumes: int = 8):
    """Supervised execution: run ``plan``; on a terminal fault, restore
    the newest checkpoint and re-execute the continuation plan from the
    following round, up to ``max_resumes`` times.

    Returns ``(host, stats)`` like any executor; the executor's
    ``exec_stats`` afterwards carries the *lifetime* fault counters
    (``faults_injected``/``retries`` across all attempts, plus
    ``resumes``).  With ``checkpoint=None`` terminal faults propagate —
    recovery needs a durable round snapshot to resume from.  A crash
    before the first commit restarts the whole plan from ``x``."""
    from .executor import EagerExecutor

    executor = executor if executor is not None else EagerExecutor()
    injector = None
    if faults is not None:
        injector = faults if isinstance(faults, FaultInjector) \
            else faults.injector()
    on_commit = checkpoint.on_commit if checkpoint is not None else None
    cur_plan, cur_x = plan, x
    resumes = 0
    while True:
        try:
            host, stats = executor.execute(cur_plan, cur_x,
                                           injector=injector, retry=retry,
                                           on_commit=on_commit)
        except PlanExecutionError:
            if checkpoint is None or resumes >= max_resumes:
                raise
            latest = checkpoint.latest()
            if latest is None:
                cur_plan, cur_x = plan, x        # nothing durable yet
            else:
                rnd, host_state = latest
                cur_plan, cur_x = resume_plan(plan, rnd + 1), host_state
            resumes += 1
            continue
        es = executor.exec_stats
        if es is not None:
            es.resumes = resumes
            if injector is not None:
                es.faults_injected = injector.faults_injected
                es.retries = injector.retries
        return host, stats
