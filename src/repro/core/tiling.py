"""Chunk / halo / parallelogram algebra for out-of-core streaming.

Row-wise decomposition of a framed (Y, X) domain.  Interior rows are
``[r, Y-r)``; chunks partition them.  All coordinates are absolute array
rows.  The algebra here is shared by every engine in
:mod:`repro.core.oocore` and by the distributed (ICI-level) engine.
"""
from __future__ import annotations

import dataclasses
from typing import List


__all__ = ["ChunkPlan", "make_chunk_plan", "split_steps"]


@dataclasses.dataclass(frozen=True)
class ChunkBounds:
    a: int  # first owned row (absolute, inclusive)
    b: int  # one-past-last owned row

    @property
    def rows(self) -> int:
        return self.b - self.a


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Row decomposition of a framed domain into ``d`` chunks."""

    Y: int
    X: int
    radius: int
    chunks: tuple  # tuple[ChunkBounds, ...]

    @property
    def d(self) -> int:
        return len(self.chunks)

    @property
    def interior_rows(self) -> int:
        return self.Y - 2 * self.radius

    def max_k_off(self) -> int:
        """Largest temporal-blocking depth supported by region sharing.

        The paper's constraint (Sec. IV-C): the halo working space
        ``W_halo * S_TB`` may not exceed a chunk, i.e. ``k*r <= min chunk
        rows`` — otherwise the sharing buffer would need rows the previous
        chunk never held.
        """
        return min(c.rows for c in self.chunks) // self.radius


def make_chunk_plan(Y: int, X: int, radius: int, d: int) -> ChunkPlan:
    interior = Y - 2 * radius
    if interior < d:
        raise ValueError(f"cannot split {interior} interior rows into {d} chunks")
    sizes = [interior // d + (1 if i < interior % d else 0) for i in range(d)]
    bounds: List[ChunkBounds] = []
    a = radius
    for s in sizes:
        bounds.append(ChunkBounds(a, a + s))
        a += s
    assert a == Y - radius
    return ChunkPlan(Y=Y, X=X, radius=radius, chunks=tuple(bounds))


def split_steps(total: int, block: int) -> List[int]:
    """Split ``total`` time steps into blocks of ``block`` (+ residual).

    Mirrors Alg. 1 lines 1–3 / 7–14: ``n`` steps become ``ceil(n/k)`` rounds
    whose last round runs the residual ``n % k`` steps.
    """
    if total <= 0:
        return []
    if block <= 0:
        raise ValueError("block must be positive")
    out = [block] * (total // block)
    if total % block:
        out.append(total % block)
    return out
