"""L2 (inter-chip) SO2DR execution backend: shard_map + ppermute.

The paper stops at a single GPU.  Its core trade — redundant computation in
overlap regions in exchange for uninterrupted locality at the faster memory
level — applies unchanged one level up: shard the domain over the chip mesh
and exchange halos of depth ``k_ici * r`` via ``collective_permute`` once
per ``k_ici`` steps, with every rank redundantly advancing its ghost wedges
(communication-avoiding stencils).  ``k_ici = 1`` degenerates to classic
per-step halo exchange — the ResReu analogue at this level — and is the §Perf
baseline.

Since the sharded-plan refactor this module is the *execution backend* of
the plan IR, not a standalone engine: :mod:`repro.core.shard` compiles
``(shape, stencil, mesh shape, k_ici, n)`` into a typed
:class:`~repro.core.plan.ShardedPlan` (per-rank op streams, halo-exchange
ops, plan-derived ICI accounting), and :func:`execute_sharded_plan` here
runs such a plan through the jitted ``shard_map``/``ppermute`` program.
:func:`run_distributed` remains the plan-free convenience (and the
differential-test oracle next to :func:`repro.core.reference.run_reference`).

Implementation notes:

* 2-D domain decomposition (rows over one mesh axis, columns over another);
  corner halos ride along by exchanging rows first, then exchanging columns
  of the row-extended band.
* Dirichlet frames are enforced with a *global-index mask* inside the
  in-place centre update (:func:`masked_local_steps`, shared with the
  lowered single-device simulator in :mod:`repro.core.lower`), so the
  per-rank program is uniform (no rank-special shapes) and the zero-filled
  halos `ppermute` leaves at mesh edges are provably never read by valid
  cells.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import AxisType, make_mesh, shard_map
from .lower import check_domain
from .stencil import Stencil, get_stencil

__all__ = [
    "distributed_stencil_step_fn", "run_distributed",
    "execute_sharded_plan", "masked_local_steps",
    "collective_bytes_per_round",
]


def _shift(x: jnp.ndarray, axis_name: str, direction: int, n_ranks: int) -> jnp.ndarray:
    """ppermute shift: rank p's payload goes to rank p + direction."""
    perm = [(p, p + direction) for p in range(n_ranks) if 0 <= p + direction < n_ranks]
    return jax.lax.ppermute(x, axis_name, perm)


def masked_local_steps(ext, st: Stencil, k: int, gy0, gx0, Yg: int, Xg: int):
    """``k`` fused stencil steps on an extended band, Dirichlet frames
    enforced by a global-index mask.

    ``ext`` covers global rows/cols ``[gy0, gy0+ey) x [gx0, gx0+ex)``
    (``gy0``/``gx0`` may be traced — the shard_map path derives them from
    ``axis_index``; the lowered simulator passes per-rank constants into
    one shared jit signature).  Shared by both execution backends so the
    per-rank math is one piece of code.
    """
    r = st.radius
    ey, ex = ext.shape
    # frame mask over the *centre* region only — masking the full band
    # cost an extra band-sized buffer per step (§Perf stencil iter1)
    grow = gy0 + r + jnp.arange(ey - 2 * r)   # global row per centre row
    gcol = gx0 + r + jnp.arange(ex - 2 * r)
    interior = (
        ((grow >= r) & (grow < Yg - r))[:, None]
        & ((gcol >= r) & (gcol < Xg - r))[None, :]
    )
    # unrolled k-step loop: k is small and static; unrolling lets XLA
    # fuse shift/FMA chains across steps instead of forcing a full
    # band materialization at every scan iteration (§Perf stencil iter2)
    for _ in range(k):
        centre = jnp.where(interior, st.step_valid(ext), ext[r:-r, r:-r])
        ext = ext.at[r:-r, r:-r].set(centre)
    return ext


def _local_rounds(
    own: jnp.ndarray,
    st: Stencil,
    k: int,
    rounds: int,
    row_axis: str,
    col_axis: str,
    n_rows_ranks: int,
    n_col_ranks: int,
    Yg: int,
    Xg: int,
) -> jnp.ndarray:
    """``rounds`` rounds of (halo exchange + k fused local steps)."""
    r = st.radius
    hk = k * r
    ly, lx = own.shape
    row_id = jax.lax.axis_index(row_axis)
    col_id = jax.lax.axis_index(col_axis)

    # global coordinates of the extended band (traced, uniform program)
    gy0 = row_id * ly - hk
    gx0 = col_id * lx - hk

    def one_round(own, _):
        # exchange row halos (full local width), then column halos of the
        # row-extended band (corners ride along)
        top = _shift(own[-hk:], row_axis, +1, n_rows_ranks)
        bot = _shift(own[:hk], row_axis, -1, n_rows_ranks)
        ext = jnp.concatenate([top, own, bot], axis=0)
        left = _shift(ext[:, -hk:], col_axis, +1, n_col_ranks)
        right = _shift(ext[:, :hk], col_axis, -1, n_col_ranks)
        ext = jnp.concatenate([left, ext, right], axis=1)

        ext = masked_local_steps(ext, st, k, gy0, gx0, Yg, Xg)
        return ext[hk:-hk, hk:-hk], None

    own, _ = jax.lax.scan(one_round, own, None, length=rounds)
    return own


def distributed_stencil_step_fn(
    name: str,
    k_ici: int,
    n_steps: int,
    mesh,
    row_axis: str = "data",
    col_axis: str = "model",
):
    """Build the jitted shard_map program advancing a framed global domain
    by ``n_steps`` (``ceil(n/k)`` rounds; n must be divisible by k for the
    uniform scan — the launcher enforces it)."""
    st = get_stencil(name)
    if n_steps % k_ici:
        raise ValueError("n_steps must be divisible by k_ici (uniform scan)")
    rounds = n_steps // k_ici
    n_row = mesh.shape[row_axis]
    n_col = mesh.shape[col_axis]

    def global_fn(x: jnp.ndarray) -> jnp.ndarray:
        Yg, Xg = x.shape

        def local(own):
            return _local_rounds(
                own, st, k_ici, rounds, row_axis, col_axis,
                n_row, n_col, Yg, Xg,
            )

        spec = P(row_axis, col_axis)
        return shard_map(
            local, mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False
        )(x)

    return jax.jit(global_fn)


def run_distributed(x, name: str, n_steps: int, k_ici: int, mesh,
                    row_axis: str = "data", col_axis: str = "model"):
    fn = distributed_stencil_step_fn(name, k_ici, n_steps, mesh, row_axis, col_axis)
    return fn(x)


def execute_sharded_plan(plan, x, mesh=None, row_axis: str = "data",
                         col_axis: str = "model"):
    """Run a :class:`~repro.core.plan.ShardedPlan` on the shard_map
    backend.

    ``mesh`` defaults to a fresh ``plan.mesh_shape`` device mesh (the
    caller's environment must have enough devices); an explicit mesh
    must match the plan's shape.  The plan carries the full geometry, so
    this is the point where "one plan drives the multi-chip engine":
    the schedule the accounting was derived from is the schedule that
    executes."""
    # shared geometry checks, before any mesh is built: both backends
    # reject identically by construction
    if getattr(plan, "trailing", ()):
        raise ValueError(
            f"plan models trailing axes {plan.trailing}; trailing plans "
            "are dry-run-only (byte/flop accounting) and cannot execute")
    check_domain(plan, x)
    if mesh is None:
        mesh = make_mesh(plan.mesh_shape, (row_axis, col_axis),
                         axis_types=(AxisType.Auto,) * 2)
    shape = (mesh.shape[row_axis], mesh.shape[col_axis])
    if shape != tuple(plan.mesh_shape):
        raise ValueError(
            f"mesh shape {shape} does not match plan mesh {plan.mesh_shape}")
    fn = distributed_stencil_step_fn(plan.stencil, plan.k_ici, plan.n,
                                     mesh, row_axis, col_axis)
    return fn(jnp.asarray(x))


def collective_bytes_per_round(
    local_shape: Tuple[int, int], radius: int, k_ici: int, itemsize: int
) -> int:
    """Analytic per-rank ICI bytes per round (send side): two row halos of
    ``k*r`` rows (full width) + two column halos of the extended height.

    Since the sharded-plan refactor this is the *formula form* of
    :attr:`repro.core.plan.ShardedPlan.collective_bytes_per_round`, which
    derives the same number from the plan's HaloSend ops (equal for
    interior ranks; property-tested in ``tests/test_shard_plan.py``)."""
    ly, lx = local_shape
    hk = k_ici * radius
    rows = 2 * hk * lx
    cols = 2 * hk * (ly + 2 * hk)
    return (rows + cols) * itemsize
