"""Sharded-plan planner: (shape, stencil, mesh shape, k_ici, n) → per-rank
op streams (the L2 analogue of the engine planners in
:mod:`repro.core.oocore`).

The multi-chip engine in :mod:`repro.core.distributed` runs the paper's
trade one level up: shard the domain over the chip mesh and exchange
halos of depth ``k_ici * r`` once per ``k_ici`` steps, every rank
redundantly advancing its ghost wedges (communication-avoiding stencils,
cf. Reguly & Mudalige, arXiv 1709.02125).  Until now that engine was the
only part of the system bypassing the typed plan IR.  This module
compiles the same schedule into a :class:`~repro.core.plan.ShardedPlan`:

* one op stream per :class:`~repro.core.plan.DeviceShard` — per round a
  row-halo exchange (``HaloSend``/``HaloRecv`` on the owned band), a
  column-halo exchange on the row-extended band (corners ride along),
  and a :class:`~repro.core.plan.ShardKernel` running ``k_ici`` masked
  fused steps before cropping back to the owned region;
* a global barrier structure (``plan.barriers``): sends and recvs live
  in separate phases, so any executor that honours phase order is
  lockstep-correct and deadlock-free by construction;
* plan-derived accounting: per-rank ICI bytes, ghost-wedge redundancy,
  and ``collective_bytes_per_round`` all read off the op streams exactly
  like :class:`~repro.core.plan.TransferStats` reads off an
  :class:`~repro.core.plan.ExecutionPlan`.

Executors: :class:`repro.core.executor.DryRunExecutor` costs a sharded
plan with zero devices; :class:`repro.core.executor.ShardedSimExecutor`
runs the per-rank streams through :func:`repro.core.lower.lower_sharded`
stage programs on a single device; and
:class:`repro.core.executor.ShardMapExecutor` dispatches to the
``shard_map``/``ppermute`` backend in :mod:`repro.core.distributed`.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from .plan import (
    DeviceShard, HaloRecv, HaloSend, ShardKernel, ShardLoad, ShardOp,
    ShardStore, ShardedPlan,
)
from .stencil import get_stencil

__all__ = ["compile_sharded", "ghost_wedge_elements", "shard_working_set"]


def shard_working_set(ly: int, lx: int, hk: int, itemsize: int,
                      trailing: Tuple[int, ...] = ()) -> int:
    """Bytes resident on a device while one round's kernel runs: the
    halo-extended input band plus the equally sized output band (the
    shard_map backend and the lockstep simulator both hold exactly this
    pair), times any unsharded trailing axes."""
    t_mult = math.prod(trailing) if trailing else 1
    return 2 * (ly + 2 * hk) * (lx + 2 * hk) * itemsize * t_mult


def _overlap(lo: int, hi: int, lo2: int, hi2: int) -> int:
    return max(0, min(hi, hi2) - max(lo, lo2))


def ghost_wedge_elements(Y: int, X: int, radius: int, k_ici: int, n: int,
                         mesh_shape: Tuple[int, int]) -> int:
    """Closed-form element-update count of the k_ici ghost-wedge schedule.

    Every rank updates the interior portion of its extended band's
    centre — ``(ly + 2*k*r - 2r) x (lx + 2*k*r - 2r)`` clipped to the
    global interior — on each of the ``k_ici`` steps of every round, so
    redundant work grows with the halo depth ``k_ici * r`` while the
    number of collective phases shrinks as ``1/k_ici``.  The planner's
    per-op ``elements`` sum to exactly this value (property-tested in
    ``tests/test_shard_plan.py``)."""
    n_row, n_col = mesh_shape
    ly, lx = Y // n_row, X // n_col
    hk = k_ici * radius
    r = radius
    total = 0
    for i in range(n_row):
        for j in range(n_col):
            y0, x0 = i * ly - hk, j * lx - hk
            rows = _overlap(y0 + r, y0 + ly + 2 * hk - r, r, Y - r)
            cols = _overlap(x0 + r, x0 + lx + 2 * hk - r, r, X - r)
            total += (n // k_ici) * k_ici * rows * cols
    return total


def compile_sharded(stencil, Y: int, X: int, n: int, k_ici: int,
                    mesh_shape: Tuple[int, int],
                    itemsize: int = 4,
                    c_dev: Optional[int] = None,
                    trailing: Tuple[int, ...] = ()) -> ShardedPlan:
    """Compile ``(shape, stencil, mesh shape, k_ici, n)`` into per-rank
    schedules — geometry only, no arrays and no devices touched.

    Feasibility mirrors the execution backend: the domain must divide
    evenly over the mesh (``shard_map`` requirement), ``n`` must be a
    multiple of ``k_ici`` (uniform scan), and the halo depth
    ``k_ici * r`` must fit inside a shard (one-hop ``ppermute``
    neighbour exchange).

    ``c_dev`` (bytes) bounds a shard's resident working set — the
    in/out halo-extended band pair (:func:`shard_working_set`); a shard
    that exceeds it is rejected here with a pointer at
    :func:`repro.core.hierarchy.compile_hierarchical`, which streams the
    band chunk-wise instead.  ``None`` skips the check (the historical
    behaviour).  ``trailing`` models extra unsharded axes (e.g. the
    third axis of a 3-D domain streamed wholesale): byte/flop/element
    accounting scales by the trailing volume; only ``trailing=()`` plans
    are executable."""
    st = get_stencil(stencil) if isinstance(stencil, str) else stencil
    r = st.radius
    n_row, n_col = mesh_shape
    if n_row < 1 or n_col < 1:
        raise ValueError(f"bad mesh shape {mesh_shape}")
    if n <= 0 or k_ici <= 0 or n % k_ici:
        raise ValueError(
            f"n={n} must be a positive multiple of k_ici={k_ici} "
            "(uniform scan, same constraint as the shard_map backend)")
    if Y % n_row or X % n_col:
        raise ValueError(
            f"domain ({Y}, {X}) does not divide evenly over mesh "
            f"{mesh_shape} (shard_map requires uniform shards)")
    ly, lx = Y // n_row, X // n_col
    hk = k_ici * r
    if (n_row > 1 and hk >= ly) or (n_col > 1 and hk >= lx):
        raise ValueError(
            f"halo depth k_ici*r={hk} does not fit in a ({ly}, {lx}) "
            "shard (one-hop neighbour exchange)")
    if any(t < 2 * r + 1 for t in trailing):
        raise ValueError(
            f"trailing axes {trailing} need at least 2r+1={2 * r + 1} "
            "points each (frame + one interior point)")
    if c_dev is not None:
        ws = shard_working_set(ly, lx, hk, itemsize, trailing)
        if ws > c_dev:
            raise ValueError(
                f"shard working set {ws} bytes (in/out band pair for a "
                f"({ly}, {lx}) shard with halo {hk}) exceeds the device "
                f"budget c_dev={c_dev}; use "
                "repro.core.hierarchy.compile_hierarchical to stream the "
                "shard chunk-wise")
    rounds = n // k_ici
    t_mult = math.prod(trailing) if trailing else 1
    t_interior = math.prod(t - 2 * r for t in trailing) if trailing else 1

    shards = tuple(
        DeviceShard(rank=i * n_col + j, row=i, col=j,
                    y0=i * ly, y1=(i + 1) * ly,
                    x0=j * lx, x1=(j + 1) * lx)
        for i in range(n_row) for j in range(n_col))
    streams: List[List[ShardOp]] = [[] for _ in shards]
    barriers: List[str] = []

    def phase(label: str) -> int:
        barriers.append(label)
        return len(barriers) - 1

    shard_bytes = ly * lx * itemsize * t_mult
    row_halo = hk * lx * itemsize * t_mult            # full local width
    col_halo = hk * (ly + 2 * hk) * itemsize * t_mult  # row-extended height

    p = phase("load")
    for sh in shards:
        streams[sh.rank].append(ShardLoad(
            rank=sh.rank, box=sh.box, nbytes=shard_bytes, round=0, phase=p))

    for rnd in range(rounds):
        # row halos of the owned band, then column halos of the
        # row-extended band — the ppermute order of _local_rounds, which
        # carries the corner halos along with the column exchange
        p = phase(f"r{rnd}:row-send")
        for sh in shards:
            if sh.row + 1 < n_row:
                streams[sh.rank].append(HaloSend(
                    rank=sh.rank, dst=sh.rank + n_col, axis=0, side="hi",
                    depth=hk, nbytes=row_halo, round=rnd, phase=p))
            if sh.row > 0:
                streams[sh.rank].append(HaloSend(
                    rank=sh.rank, dst=sh.rank - n_col, axis=0, side="lo",
                    depth=hk, nbytes=row_halo, round=rnd, phase=p))
        p = phase(f"r{rnd}:row-recv")
        for sh in shards:
            up = sh.rank - n_col if sh.row > 0 else -1
            dn = sh.rank + n_col if sh.row + 1 < n_row else -1
            streams[sh.rank].append(HaloRecv(
                rank=sh.rank, src=up, axis=0, side="lo", depth=hk,
                nbytes=row_halo if up >= 0 else 0, round=rnd, phase=p))
            streams[sh.rank].append(HaloRecv(
                rank=sh.rank, src=dn, axis=0, side="hi", depth=hk,
                nbytes=row_halo if dn >= 0 else 0, round=rnd, phase=p))
        p = phase(f"r{rnd}:col-send")
        for sh in shards:
            if sh.col + 1 < n_col:
                streams[sh.rank].append(HaloSend(
                    rank=sh.rank, dst=sh.rank + 1, axis=1, side="hi",
                    depth=hk, nbytes=col_halo, round=rnd, phase=p))
            if sh.col > 0:
                streams[sh.rank].append(HaloSend(
                    rank=sh.rank, dst=sh.rank - 1, axis=1, side="lo",
                    depth=hk, nbytes=col_halo, round=rnd, phase=p))
        p = phase(f"r{rnd}:col-recv")
        for sh in shards:
            lf = sh.rank - 1 if sh.col > 0 else -1
            rt = sh.rank + 1 if sh.col + 1 < n_col else -1
            streams[sh.rank].append(HaloRecv(
                rank=sh.rank, src=lf, axis=1, side="lo", depth=hk,
                nbytes=col_halo if lf >= 0 else 0, round=rnd, phase=p))
            streams[sh.rank].append(HaloRecv(
                rank=sh.rank, src=rt, axis=1, side="hi", depth=hk,
                nbytes=col_halo if rt >= 0 else 0, round=rnd, phase=p))
        p = phase(f"r{rnd}:compute")
        h, w = ly + 2 * hk, lx + 2 * hk
        for sh in shards:
            gy0, gx0 = sh.y0 - hk, sh.x0 - hk
            rows = _overlap(gy0 + r, gy0 + h - r, r, Y - r)
            cols = _overlap(gx0 + r, gx0 + w - r, r, X - r)
            elements = k_ici * rows * cols * t_interior
            streams[sh.rank].append(ShardKernel(
                rank=sh.rank, stencil=st.name, steps=k_ici,
                gy0=gy0, gx0=gx0, h=h, w=w,
                hbm_bytes=2 * h * w * itemsize * t_mult,
                flops=elements * st.flops_per_elem,
                elements=elements, round=rnd, phase=p))

    p = phase("store")
    for sh in shards:
        streams[sh.rank].append(ShardStore(
            rank=sh.rank, box=sh.box, nbytes=shard_bytes,
            round=rounds - 1, phase=p))

    exact = n * (Y - 2 * r) * (X - 2 * r) * t_interior
    return ShardedPlan(
        stencil=st.name, Y=Y, X=X, itemsize=itemsize, n=n, k_ici=k_ici,
        mesh_shape=(n_row, n_col), radius=r, shards=shards,
        streams=tuple(tuple(s) for s in streams), barriers=tuple(barriers),
        exact_elements=exact, trailing=tuple(trailing))
