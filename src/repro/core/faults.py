"""Deterministic fault injection for plan execution.

A :class:`FaultPlan` is a seeded, reproducible schedule of injected
failures: each :class:`FaultTrigger` names a ``(round, chunk, op_class,
kind)`` site, and a per-run :class:`FaultInjector` raises the matching
:class:`InjectedFault` the moment the lowered stage loop reaches that
site — *before* the op's closure executes, so the op has not mutated any
slot yet and a retry is simply a re-attempt.  That makes every recovery
path in :mod:`repro.core.recovery` testable with zero devices and zero
real flakiness:

* ``transient_transfer`` — a recoverable wire hiccup; the stage loop
  retries it under a bounded-exponential-backoff :class:`RetryPolicy`.
* ``kernel_fault`` — a terminal device-side failure (an XLA abort); the
  run dies with the last committed round intact.
* ``rank_loss`` — a mesh peer disappeared (pod-slice preemption); the
  elastic harness in :mod:`repro.launch.elastic` re-plans the remaining
  rounds on the surviving mesh.
* ``slot_exhausted`` — device slot storage ran out; terminal for the
  run, but the leased slots still return to the pool (the try/finally
  discipline in :meth:`repro.core.lower.CompiledPlan.execute`).

For single-device :class:`~repro.core.plan.ExecutionPlan` stages
``chunk`` is the plan's chunk index; for sharded plans the same field
addresses the *rank*.  This module is dependency-free on purpose — the
lowering layer imports it, never the other way around.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = [
    "TRANSIENT_TRANSFER", "KERNEL_FAULT", "RANK_LOSS", "SLOT_EXHAUSTED",
    "FAULT_KINDS",
    "InjectedFault", "TransientTransferError", "KernelFault",
    "RankLossFault", "SlotExhaustedError",
    "FaultTrigger", "FaultPlan", "FaultInjector", "RetryPolicy", "consult",
]

TRANSIENT_TRANSFER = "transient_transfer"
KERNEL_FAULT = "kernel_fault"
RANK_LOSS = "rank_loss"
SLOT_EXHAUSTED = "slot_exhausted"
FAULT_KINDS = (TRANSIENT_TRANSFER, KERNEL_FAULT, RANK_LOSS, SLOT_EXHAUSTED)


class InjectedFault(Exception):
    """Base of every injected failure.  ``transient`` faults are safe to
    retry in place (the faulting op never ran); terminal faults abort
    the run with the last committed round as the recovery point."""

    kind = "injected"
    transient = False

    def __init__(self, round: int, chunk: int, op_class: str):
        self.round = round
        self.chunk = chunk
        self.op_class = op_class
        super().__init__(
            f"{self.kind} injected at round={round} chunk={chunk} "
            f"op={op_class}")


class TransientTransferError(InjectedFault):
    """A recoverable transfer hiccup (dropped DMA, PCIe retry)."""

    kind = TRANSIENT_TRANSFER
    transient = True


class KernelFault(InjectedFault):
    """A terminal device-side kernel failure."""

    kind = KERNEL_FAULT


class RankLossFault(InjectedFault):
    """A mesh peer disappeared mid-round (preemption).  ``chunk``
    addresses the lost rank for sharded plans."""

    kind = RANK_LOSS

    @property
    def rank(self) -> int:
        return self.chunk


class SlotExhaustedError(InjectedFault):
    """Device slot storage exhausted — terminal for this run."""

    kind = SLOT_EXHAUSTED


_FAULT_TYPES = {
    TRANSIENT_TRANSFER: TransientTransferError,
    KERNEL_FAULT: KernelFault,
    RANK_LOSS: RankLossFault,
    SLOT_EXHAUSTED: SlotExhaustedError,
}


@dataclasses.dataclass(frozen=True)
class FaultTrigger:
    """One injection site: fire ``kind`` the first ``count`` times the
    executor reaches ``(round, chunk, op_class)``.

    ``chunk=None`` matches any chunk/rank of the round; ``op_class`` is
    an :data:`repro.core.lower.OP_TAGS` name or ``"*"``.  ``count > 1``
    models a fault that persists across retries (a transient trigger
    with ``count <= max_retries`` is fully absorbed by the retry loop)."""

    round: int
    chunk: Optional[int]
    op_class: str
    kind: str
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.round < 0 or self.count < 1:
            raise ValueError(f"bad trigger {self!r}")

    def matches(self, rnd: int, chunk: int, op_class: str) -> bool:
        return (self.round == rnd
                and (self.chunk is None or self.chunk == chunk)
                and (self.op_class == "*" or self.op_class == op_class))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, deterministic schedule of injected faults.  Build
    one per scenario; mint a fresh mutable :class:`FaultInjector` per
    run (or per run *sequence* when counting across resumes)."""

    triggers: Tuple[FaultTrigger, ...]

    def __init__(self, triggers: Sequence[FaultTrigger]):
        object.__setattr__(self, "triggers", tuple(triggers))

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    @classmethod
    def seeded(cls, seed: int, plan, n_faults: int = 1,
               kinds: Sequence[str] = (TRANSIENT_TRANSFER,),
               op_classes: Sequence[str] = ("H2D",)) -> "FaultPlan":
        """Derive a reproducible fault schedule from a plan's geometry.

        Sites are drawn (with a :class:`random.Random` seeded by
        ``seed``) from the plan's real ``(round, chunk)`` stage keys —
        or ``(round, rank)`` pairs for a sharded plan — so the same seed
        against the same plan always injects the same faults."""
        rng = random.Random(seed)
        if hasattr(plan, "streams"):        # ShardedPlan
            keys = [(r, rank) for r in range(plan.rounds)
                    for rank in range(plan.n_ranks)]
        else:
            keys = sorted({k for k, _ in plan.stages() if k is not None})
        if not keys:
            raise ValueError("plan has no chunk stages to fault")
        triggers = [
            FaultTrigger(round=rnd, chunk=chunk,
                         op_class=rng.choice(list(op_classes)),
                         kind=rng.choice(list(kinds)))
            for rnd, chunk in (rng.choice(keys) for _ in range(n_faults))
        ]
        return cls(triggers)


class FaultInjector:
    """Per-run-sequence mutable state of a :class:`FaultPlan`: remaining
    trigger counts plus lifetime ``faults_injected``/``retries`` tallies
    (the source the recovery loop copies into :class:`ExecStats`)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._remaining: List[int] = [t.count for t in plan.triggers]
        self.faults_injected = 0
        self.retries = 0

    def before_op(self, rnd: int, chunk: int, op_class: str) -> None:
        """Raise the scheduled fault, if any, for this op site.  Called
        by the stage loop *before* the op's closure runs, so a raising
        site leaves all slots exactly as they were."""
        for i, trig in enumerate(self.plan.triggers):
            if self._remaining[i] > 0 and trig.matches(rnd, chunk, op_class):
                self._remaining[i] -= 1
                self.faults_injected += 1
                raise _FAULT_TYPES[trig.kind](rnd, chunk, op_class)

    def pending(self) -> int:
        """Triggers not yet fully fired."""
        return sum(1 for r in self._remaining if r > 0)

    def with_round_offset(self, offset: int) -> "FaultInjector":
        """A view translating local round ``r`` to global ``r + offset``
        — what the elastic harness hands a one-round continuation plan
        so triggers keep addressing global rounds."""
        return _OffsetInjector(self, offset)


class _OffsetInjector:
    def __init__(self, inner: FaultInjector, offset: int):
        self._inner = inner
        self._offset = offset

    def before_op(self, rnd: int, chunk: int, op_class: str) -> None:
        self._inner.before_op(rnd + self._offset, chunk, op_class)

    @property
    def faults_injected(self) -> int:
        return self._inner.faults_injected

    @property
    def retries(self) -> int:
        return self._inner.retries

    def with_round_offset(self, offset: int) -> "FaultInjector":
        return _OffsetInjector(self._inner, self._offset + offset)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient faults.

    ``sleep`` is injectable so tests never actually wait; the default
    delays are tiny because the injected faults they absorb are
    simulated — a real deployment would tune ``backoff_s`` to its
    transport."""

    max_retries: int = 3
    backoff_s: float = 0.001
    backoff_factor: float = 2.0
    max_backoff_s: float = 0.25
    sleep: Callable[[float], None] = time.sleep

    def delay(self, attempt: int) -> float:
        return min(self.backoff_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)


def consult(injector, retry: Optional[RetryPolicy],
            rnd: int, chunk: int, op_class: str) -> None:
    """The stage loop's injection point: ask ``injector`` whether this
    op site faults; absorb transient faults by retrying (with backoff)
    up to ``retry.max_retries`` times; re-raise anything terminal or
    past the retry budget.  Counters accrue on the injector itself so
    they survive the raise."""
    attempt = 0
    while True:
        try:
            injector.before_op(rnd, chunk, op_class)
            return
        except InjectedFault as f:
            if not f.transient or retry is None or attempt >= retry.max_retries:
                raise
            retry.sleep(retry.delay(attempt))
            attempt += 1
            if hasattr(injector, "_inner"):
                injector._inner.retries += 1
            else:
                injector.retries += 1
