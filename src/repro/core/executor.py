"""Pluggable executors over :class:`repro.core.plan.ExecutionPlan`.

Three interpreters of the same op schedule:

* :class:`EagerExecutor` — walks ops in plan order; reproduces the
  pre-refactor engine behavior bit-for-bit against the oracle.
* :class:`DoubleBufferedExecutor` — software-pipelined: chunk ``i+1``'s
  H2D is issued while chunk ``i``'s kernels/D2H are still in flight
  (JAX async dispatch carries the overlap; nothing blocks until a
  ``HostCommit`` barrier forces the staged device handles with
  ``jax.block_until_ready``).  This is the paper's multi-stream overlap
  (Sec. II, N_strm = 3), previously impossible with inline engine loops.
* :class:`DryRunExecutor` — walks no device work at all and returns the
  plan-derived :class:`TransferStats`; the autotuner costs the whole
  configuration sweep with it.  It also costs multi-device
  :class:`~repro.core.plan.ShardedPlan` schedules with zero devices.

Sharded plans (:mod:`repro.core.shard`) add two more:

* :class:`ShardedSimExecutor` — lowers the per-rank op streams to
  lockstep stage programs (:func:`repro.core.lower.lower_sharded`) and
  runs them on a single device, halos moving through a mailbox; the
  differential counterpart of the shard_map oracle.
* :class:`ShardMapExecutor` — dispatches the plan to the real
  ``shard_map``/``ppermute`` backend in :mod:`repro.core.distributed`.

The device executors run plans through the lowering layer by default
(:func:`repro.core.lower.lower`): ops become per-(round, chunk) stage
programs of pre-bound closures (no per-op ``isinstance`` dispatch),
FusedKernel ops resolve through the kernel-dispatch registry
(:mod:`repro.kernels.dispatch`), band heights are padded to per-plan
shape buckets so chunks/rounds share one compiled kernel signature, and
an :class:`~repro.core.lower.ExecStats` with per-op-class wall clock and
compilation-cache counters lands on ``executor.exec_stats`` after every
run.  ``lowered=False`` falls back to the original op-at-a-time
interpreter (:class:`_DeviceState`) — results are bitwise identical.

All executors return ``(host_array | None, TransferStats)`` where the
stats always come from :meth:`ExecutionPlan.stats` — accounting is a
property of the *plan*, not of how it was executed.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compress import get_codec
from .lower import ExecStats, KernelCache, lower, lower_sharded, validate_domain
from .plan import (
    BufferRead, BufferWrite, Compress, D2H, Decompress, ExecutionPlan,
    FusedKernel, H2D, HostCommit, ShardedPlan, TransferStats,
)
from .reference import multi_step_band, multi_step_box

__all__ = [
    "EagerExecutor", "DoubleBufferedExecutor", "DryRunExecutor",
    "ShardedSimExecutor", "ShardMapExecutor",
    "get_executor", "EXECUTORS",
]

# fused-step implementation signature:
#   fn(band, stencil_name, steps, keep_top, keep_bottom) -> band
FusedStep = Callable[..., jnp.ndarray]


class _StagedWrite:
    """One staged D2H.

    ``rows`` stays an async device handle until the HostCommit barrier —
    also for compressed transfers: the codec's encode/decode round trip
    runs at commit time (the first point the bytes are forced anyway), so
    compression never adds a per-chunk sync and the double-buffered
    overlap is preserved.  ``pending`` is True only between a d2h-side
    Compress and its Decompress; committing a pending entry is a plan
    bug."""

    __slots__ = ("box", "rows", "codec", "pending")

    def __init__(self, box, rows, codec=None, pending=False):
        self.box = box            # destination host Box
        self.rows = rows          # async jnp handle (or np box payload)
        self.codec = codec        # codec name; round trip runs at commit
        self.pending = pending


class _DeviceState:
    """Register/buffer/staging state for the legacy op-at-a-time path.

    Codec ops run for real: the ``Compress``/``Decompress`` pairs the
    rewrite pass emits encode the transferred rows into an actual byte
    payload and decode them on the far side (this container is CPU, so
    the codec's device half runs in NumPy).  H2D encodes eagerly at the
    Compress op (a pure host-side read; the ``jnp.asarray`` hop carries
    the encoded bytes) and decodes at the Decompress op; the D2H round
    trip is recorded at the Decompress op but physically runs at the
    HostCommit barrier — the first point the device bytes are forced
    anyway — so compression never introduces a per-chunk sync.  Lossless
    codecs therefore round-trip bit-exactly through real encoded bytes;
    accounting still comes from the plan.

    The ``identity`` codec is fast-pathed: its encode/decode is a pure
    byte copy, so the round trip is skipped entirely — the H2D/D2H is
    already the copy — while wire-byte accounting (plan-derived) is
    untouched."""

    def __init__(self, host: np.ndarray, fused_step: Optional[FusedStep]):
        self.host = host
        self.fused_step = fused_step   # None = reference (banded path only)
        self.regs: Dict[str, jnp.ndarray] = {}
        self.bufs: Dict[str, jnp.ndarray] = {}
        self.staged: List[_StagedWrite] = []
        # reg -> (device payload, shape, dtype) between Compress(h2d) and
        # Decompress(h2d); reg -> codec name between Compress(d2h) and D2H
        self.h2d_wire: Dict[str, Tuple[jnp.ndarray, tuple, np.dtype]] = {}
        self.d2h_codec: Dict[str, str] = {}

    def issue_h2d(self, op: H2D) -> None:
        if op.reg in self.h2d_wire:
            return   # wire hop already happened at Compress time
        self.regs[op.reg] = jnp.asarray(self.host[op.box.slices()])

    def _compress(self, op: Compress) -> None:
        if op.codec == "identity":
            return   # fast path: the transfer op itself is the pure copy
        if op.direction == "h2d":
            rows = self.host[op.box.slices()]
            payload = get_codec(op.codec).encode(rows)
            # the wire hop: encoded bytes (not raw rows) go to the device
            self.h2d_wire[op.reg] = (jnp.asarray(payload), rows.shape, rows.dtype)
        else:
            self.d2h_codec[op.reg] = op.codec   # encode happens at the D2H

    def _decompress(self, op: Decompress) -> None:
        if op.codec == "identity":
            return
        if op.direction == "h2d":
            payload, shape, dtype = self.h2d_wire.pop(op.reg)
            decoded = get_codec(op.codec).decode(np.asarray(payload), shape, dtype)
            self.regs[op.reg] = jnp.asarray(decoded)
        else:
            entry = self.staged[-1]
            assert entry.pending and entry.box == op.box, \
                "Decompress does not match the staged D2H"
            entry.pending = False   # round trip scheduled; runs at commit

    def issue(self, op) -> None:
        if isinstance(op, H2D):
            self.issue_h2d(op)
        elif isinstance(op, Compress):
            self._compress(op)
        elif isinstance(op, Decompress):
            self._decompress(op)
        elif isinstance(op, BufferWrite):
            self.bufs[op.buf] = self.regs[op.reg][op.reg_box.slices()]
        elif isinstance(op, BufferRead):
            shared = self.bufs.pop(op.buf)
            self.regs[op.reg] = jnp.concatenate(
                [shared, self.regs.pop(op.src)], axis=op.axis)
        elif isinstance(op, FusedKernel):
            band = self.regs[op.reg]
            # banded = a classic 2-D row band (full width, frame columns
            # along): the registered fused-step kernels apply.  Anything
            # else (3-D tiles, column chunks) runs the N-D reference.
            if len(op.shape_in) == 2 and op.keep_lo[1] and op.keep_hi[1]:
                fn = self.fused_step or multi_step_band
                self.regs[op.reg] = fn(
                    band, op.stencil, op.steps,
                    keep_top=op.keep_lo[0], keep_bottom=op.keep_hi[0])
            else:
                self.regs[op.reg] = multi_step_box(
                    band, op.stencil, op.steps,
                    keep_lo=op.keep_lo, keep_hi=op.keep_hi)
        elif isinstance(op, D2H):
            band = self.regs.pop(op.reg)   # last use of the register
            codec = self.d2h_codec.pop(op.reg, None)
            self.staged.append(_StagedWrite(
                op.box, rows=band[op.reg_box.slices()],
                codec=codec, pending=codec is not None))
        elif isinstance(op, HostCommit):
            self.commit()
        else:  # pragma: no cover - planner/executor version skew
            raise TypeError(f"unknown op {op!r}")

    def commit(self) -> None:
        for entry in self.staged:
            assert not entry.pending, \
                "staged D2H committed before its Decompress"
            jax.block_until_ready(entry.rows)
        for entry in self.staged:
            rows = np.asarray(entry.rows)
            if entry.codec is not None:
                # the wire round trip: device-side encode, host-side decode
                codec = get_codec(entry.codec)
                rows = codec.decode(codec.encode(rows), rows.shape, rows.dtype)
            self.host[entry.box.slices()] = rows
        self.staged.clear()


class _LoweredExecutorBase:
    """Shared compile-then-run machinery for the device executors.

    Re-entrant: ``execute`` may be called from several threads at once
    (the serving layer compiles/admits jobs concurrently).  The lowering
    memo is a keyed, locked cache; ``exec_stats`` is thread-local on
    read (each thread sees its own last run) with a cross-thread
    fallback to the most recent run, which preserves the single-threaded
    ``executor.exec_stats`` idiom everywhere else."""

    name = "base"
    _pipeline = False
    _MEMO_CAP = 64   # FIFO bound on retained (plan -> CompiledPlan) entries

    def __init__(self, fused_step: Optional[FusedStep] = None,
                 policy=None, lowered: bool = True, slot_pool=None):
        self.fused_step = fused_step
        self.policy = policy
        self.lowered = lowered
        # kernel-signature cache shared across execute() calls: re-running
        # a plan (or one with the same shape buckets) is all hits
        self.kernel_cache = KernelCache()
        # optional shared SlotPool: device storage leased per run and
        # returned after commit instead of allocated per CompiledPlan
        self.slot_pool = slot_pool
        # keyed lowering memo: id(plan) -> (plan, fused_step, policy,
        # compiled).  Holding the plan keeps id()/`is` identity sound, and
        # comparing the fused_step/policy snapshot invalidates an entry if
        # either public attribute was swapped between runs.
        self._lowered_memo: Dict[int, tuple] = {}
        self._memo_lock = threading.Lock()
        self._tls = threading.local()
        self._last_stats: Optional[ExecStats] = None

    @property
    def exec_stats(self) -> Optional[ExecStats]:
        stats = getattr(self._tls, "stats", None)
        return stats if stats is not None else self._last_stats

    @exec_stats.setter
    def exec_stats(self, value: Optional[ExecStats]) -> None:
        self._tls.stats = value
        self._last_stats = value

    def _compiled(self, plan: ExecutionPlan):
        key = id(plan)
        fused_step, policy = self.fused_step, self.policy
        with self._memo_lock:
            memo = self._lowered_memo.get(key)
            if (memo is not None and memo[0] is plan
                    and memo[1] is fused_step and memo[2] == policy):
                return memo[3]
        # lower outside the lock: the KernelCache is itself thread-safe,
        # so a racing duplicate lower() costs hits, not recompiles
        compiled = lower(plan, policy=policy, fused_step=fused_step,
                         kernel_cache=self.kernel_cache)
        with self._memo_lock:
            if key not in self._lowered_memo and \
                    len(self._lowered_memo) >= self._MEMO_CAP:
                self._lowered_memo.pop(next(iter(self._lowered_memo)))
            self._lowered_memo[key] = (plan, fused_step, policy, compiled)
        return compiled

    supports_injection = True

    def execute(self, plan: ExecutionPlan, x: np.ndarray,
                injector=None, retry=None, on_commit=None,
                ) -> Tuple[np.ndarray, TransferStats]:
        """Run a plan.  ``injector``/``retry``/``on_commit`` thread the
        fault-injection and checkpoint hooks through to
        :meth:`repro.core.lower.CompiledPlan.execute`; they require the
        lowered path (the legacy op-at-a-time interpreter has no op
        sites to consult)."""
        if self.lowered:
            host, stats, exec_stats = self._compiled(plan).execute(
                x, pipeline=self._pipeline, slot_pool=self.slot_pool,
                injector=injector, retry=retry, on_commit=on_commit)
            exec_stats.executor = self.name
            self.exec_stats = exec_stats
            return host, stats
        if injector is not None or retry is not None or on_commit is not None:
            raise ValueError(
                "fault injection / commit hooks require the lowered "
                "executor path (lowered=True)")
        host, stats = self._execute_legacy(plan, x)
        self.exec_stats = None
        return host, stats

    def _execute_legacy(self, plan, x):
        raise NotImplementedError


class EagerExecutor(_LoweredExecutorBase):
    """In-order interpreter: one stage program at a time, plan order."""

    name = "eager"
    _pipeline = False

    def _execute_legacy(self, plan, x):
        state = _DeviceState(validate_domain(plan, x), self.fused_step)
        for op in plan.ops:
            state.issue(op)
        state.commit()   # no-op unless a planner forgot the final barrier
        return state.host, plan.stats()


class DoubleBufferedExecutor(_LoweredExecutorBase):
    """Software-pipelined interpreter (the paper's multi-stream overlap).

    Walks the plan stage-by-stage (one stage per ``(round, chunk)``).
    Before executing stage ``i``'s kernels it issues every H2D of stage
    ``i+1`` — legal because H2D only reads committed host rows and
    commits are stage-group barriers — so the next chunk's transfer rides
    under the current chunk's kernel work exactly like the paper's
    ``N_strm = 3`` double buffering.  Correctness is untouched: data
    dependencies flow through registers/buffers, which prefetching never
    reorders.
    """

    name = "double_buffered"
    _pipeline = True

    def _execute_legacy(self, plan, x):
        state = _DeviceState(validate_domain(plan, x), self.fused_step)
        stages = plan.stages()
        prefetched: set = set()
        for j, (key, ops) in enumerate(stages):
            if key is None:          # HostCommit barrier
                for op in ops:
                    state.issue(op)
                continue
            # prefetch the next chunk's H2D — and the host-side Compress
            # feeding it — before touching this chunk's kernels; stop at
            # barriers (host rows change there)
            if j + 1 < len(stages) and stages[j + 1][0] is not None:
                for nxt in stages[j + 1][1]:
                    if isinstance(nxt, H2D) or (
                            isinstance(nxt, Compress) and nxt.direction == "h2d"):
                        state.issue(nxt)
                        prefetched.add(id(nxt))
            for op in ops:
                if id(op) in prefetched:
                    continue
                state.issue(op)
        state.commit()
        return state.host, plan.stats()


class DryRunExecutor:
    """Zero-device-work interpreter: the plan *is* the result.

    Used by :mod:`repro.core.autotune` to cost the full configuration
    sweep and by ``benchmarks/run.py --dry-run`` to exercise plan
    construction for every engine without allocating a single device
    array.  Accepts both single-device :class:`ExecutionPlan` and
    multi-device :class:`~repro.core.plan.ShardedPlan` schedules — in
    both cases the accounting is a property of the plan, so a sharded
    plan's ICI/wedge costs are known with zero devices."""

    name = "dry_run"

    def execute(self, plan,
                x: Optional[np.ndarray] = None) -> Tuple[None, TransferStats]:
        return None, plan.stats()


class ShardedSimExecutor:
    """Single-device lockstep simulator for sharded plans.

    Lowers the per-rank op streams through
    :func:`repro.core.lower.lower_sharded` (slot-bound closures, shared
    halo mailbox, one cached kernel signature for every rank x round)
    and walks the global phases in barrier order.  Differentially tested
    against the ``shard_map`` oracle: results match
    :func:`repro.core.distributed.run_distributed` to float tolerance
    with zero real devices, which is what lets CI exercise multi-chip
    schedules on a CPU container.

    Hierarchical plans (:mod:`repro.core.hierarchy`) run through the
    same entry point: the lowering layer expands each ShardKernel into
    its rank's nested stage program, and ``slot_pool`` (optional, shared
    with the serving layer) supplies the chunk-slot storage those inner
    programs lease per round."""

    name = "sharded_sim"
    supports_injection = True

    def __init__(self, slot_pool=None, kernel_cache=None):
        self.kernel_cache = kernel_cache if kernel_cache is not None \
            else KernelCache()
        self.slot_pool = slot_pool
        self.exec_stats: Optional[ExecStats] = None
        self._lowered_memo = None

    def _compiled(self, plan):
        memo = self._lowered_memo
        if memo is not None and memo[0] is plan:
            return memo[1]
        compiled = lower_sharded(plan, kernel_cache=self.kernel_cache)
        self._lowered_memo = (plan, compiled)
        return compiled

    def execute(self, plan, x: np.ndarray,
                injector=None, retry=None, on_commit=None,
                ) -> Tuple[np.ndarray, TransferStats]:
        host, stats, exec_stats = self._compiled(plan).execute(
            x, injector=injector, retry=retry, slot_pool=self.slot_pool)
        exec_stats.executor = self.name
        self.exec_stats = exec_stats
        if on_commit is not None:
            # a sharded plan stores host state once, at the end: its
            # whole run is one commit of the final round
            on_commit(plan.rounds - 1, host)
        return host, stats


class ShardMapExecutor:
    """Multi-device backend: run a sharded plan through the
    ``shard_map``/``ppermute`` program in :mod:`repro.core.distributed`.

    The plan carries the whole geometry (mesh shape, k_ici, stencil, n),
    so ``execute(plan, x)`` needs no configuration beyond an optional
    explicit mesh — by default a ``plan.mesh_shape`` mesh is built from
    the visible devices.  Stats are the plan-derived accounting, same as
    every other executor.

    Hierarchical and halo-compressed plans dispatch on their *outer
    geometry*: the backend runs one fused shard_map program per round,
    so the nested chunking and the codec round trip are sim-only
    refinements — each device holds its full band (valid when the real
    device fits it) and halos cross ``ppermute`` raw.  Stats still
    report the plan's own two-level/wire accounting."""

    name = "shard_map"

    def __init__(self, mesh=None, row_axis: str = "data",
                 col_axis: str = "model"):
        self.mesh = mesh
        self.row_axis = row_axis
        self.col_axis = col_axis
        self.exec_stats: Optional[ExecStats] = None

    def execute(self, plan,
                x: np.ndarray) -> Tuple[np.ndarray, TransferStats]:
        import time

        from .distributed import execute_sharded_plan

        t0 = time.perf_counter()
        out = np.asarray(execute_sharded_plan(plan, x, mesh=self.mesh,
                                              row_axis=self.row_axis,
                                              col_axis=self.col_axis))
        # the backend runs one fused shard_map program, not per-op
        # closures: no per-op wall clock or cache counters to report
        self.exec_stats = ExecStats(
            executor=self.name, kernel_impl="shard_map",
            kernel_calls=plan.n_ranks * plan.rounds,
            stage_count=len(plan.barriers),
            wall_s=time.perf_counter() - t0)
        return out, plan.stats()


EXECUTORS = {e.name: e for e in
             (EagerExecutor, DoubleBufferedExecutor, DryRunExecutor,
              ShardedSimExecutor, ShardMapExecutor)}

# executors that interpret single-device ExecutionPlans (what
# benchmarks.run --exec sweeps); the sharded ones take a ShardedPlan
PLAN_EXECUTORS = ("eager", "double_buffered")


def get_executor(name: str, fused_step: Optional[FusedStep] = None,
                 policy=None):
    try:
        cls = EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown executor {name!r}; known: {sorted(EXECUTORS)}")
    if cls in (DryRunExecutor, ShardedSimExecutor, ShardMapExecutor):
        if fused_step is not None or policy is not None:
            raise ValueError(
                f"executor {name!r} takes no fused_step/policy — it never "
                "dispatches single-device FusedKernel ops")
        return cls()
    return cls(fused_step, policy=policy)
