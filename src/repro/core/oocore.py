"""Out-of-core stencil engines (the paper's Sec. II/IV, Alg. 1).

Four engines, all verified equivalent to the oracle
(:func:`repro.core.reference.run_reference`):

* :class:`InCore`   — whole domain resident on device, ``k_on``-step fused
  kernels (the paper's in-core comparison code, Sec. V-D).
* :class:`NaiveTB`  — temporal blocking with *redundant transfer*: every
  round each chunk re-transfers its ``k_off*r`` halos (paper Fig. 1b).
* :class:`ResReu`   — region sharing with intermediate-result reuse (Jin et
  al. [15]): zero redundant transfer, zero redundant compute, but kernels
  are forced to single time steps interleaved with buffer reads/writes
  (paper Fig. 2b).  Implemented as parallelogram band streaming: chunk ``i``'s
  working band at step ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)``; a
  per-step carry conveys rows ``[b_i-r, b_i+(k-s)r)`` to chunk ``i+1``.
* :class:`SO2DR`    — the paper's contribution: region sharing happens once
  per chunk-round at step 0 (rows ``[b_i-kr, b_i)``), redundant computation
  is deliberately admitted in the overlap wedges, and kernels run
  ``k_on`` fused steps uninterrupted (Alg. 1 lines 7-14).

Device emulation: host state is numpy, device state is jax; every
host<->device movement and on-device buffer copy is tallied in
:class:`TransferStats` for the Sec. III analytic model and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .reference import multi_step_band, step_band
from .stencil import Stencil
from .tiling import ChunkPlan, make_chunk_plan, split_steps

__all__ = ["TransferStats", "InCore", "NaiveTB", "ResReu", "SO2DR", "get_engine"]

# fused-step implementation signature:
#   fn(band, stencil_name, steps, keep_top, keep_bottom) -> band
FusedStep = Callable[..., jnp.ndarray]


@dataclasses.dataclass
class TransferStats:
    """Byte/FLOP accounting for one engine run (paper Fig. 7 categories)."""

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    buffer_bytes: int = 0       # on-device region-sharing copies ("O/D")
    kernel_calls: int = 0
    kernel_hbm_bytes: int = 0   # per-call band read + output write traffic
    flops: int = 0
    elements_computed: int = 0  # element-updates incl. redundant ones
    exact_elements: int = 0     # n * interior elements (the useful work)

    @property
    def redundant_elements(self) -> int:
        return self.elements_computed - self.exact_elements

    @property
    def redundancy(self) -> float:
        return self.redundant_elements / max(self.exact_elements, 1)


def _account_fused(
    stats: TransferStats,
    st: Stencil,
    h: int,
    X: int,
    steps: int,
    keep_top: bool,
    keep_bottom: bool,
    itemsize: int,
) -> int:
    """Account FLOPs/bytes for one fused kernel call; returns output height."""
    keep = (int(keep_top) + int(keep_bottom)) * st.radius
    r = st.radius
    stats.kernel_calls += 1
    h_in = h
    for _ in range(steps):
        rows = h - 2 * r
        stats.elements_computed += rows * (X - 2 * r)
        stats.flops += rows * (X - 2 * r) * st.flops_per_elem
        h = rows + keep
    stats.kernel_hbm_bytes += (h_in + h) * X * itemsize
    return h


class _EngineBase:
    name: str = "base"

    def __init__(self, d: int, k_off: int, k_on: int, fused_step: Optional[FusedStep] = None):
        self.d = d
        self.k_off = k_off
        self.k_on = k_on
        self.fused_step = fused_step or multi_step_band

    def _plan(self, x: np.ndarray, st: Stencil) -> ChunkPlan:
        plan = make_chunk_plan(x.shape[0], x.shape[1], st.radius, self.d)
        if self.k_off > plan.max_k_off():
            raise ValueError(
                f"k_off={self.k_off} violates region-sharing feasibility "
                f"(k_off*r must fit in the smallest chunk; max {plan.max_k_off()})"
            )
        return plan

    def run(self, x: np.ndarray, st: Stencil, n: int) -> Tuple[np.ndarray, TransferStats]:
        raise NotImplementedError

    def _finalize(self, stats: TransferStats, x: np.ndarray, st: Stencil, n: int) -> None:
        r = st.radius
        stats.exact_elements = n * (x.shape[0] - 2 * r) * (x.shape[1] - 2 * r)


class InCore(_EngineBase):
    """Whole domain on device; the paper's in-core code with k_on-step kernels."""

    name = "incore"

    def run(self, x, st, n):
        stats = TransferStats()
        itemsize = x.dtype.itemsize
        dev = jnp.asarray(x)
        stats.h2d_bytes += dev.size * itemsize
        for m in split_steps(n, self.k_on):
            _account_fused(stats, st, dev.shape[0], dev.shape[1], m, True, True, itemsize)
            dev = self.fused_step(dev, st.name, m, keep_top=True, keep_bottom=True)
        stats.d2h_bytes += dev.size * itemsize
        out = np.asarray(dev)
        self._finalize(stats, x, st, n)
        return out, stats


class NaiveTB(_EngineBase):
    """Temporal blocking with redundant halo transfer (paper Fig. 1b)."""

    name = "naive_tb"

    def run(self, x, st, n):
        stats = TransferStats()
        r = st.radius
        plan = self._plan(x, st)
        itemsize = x.dtype.itemsize
        host = np.asarray(x).copy()
        Y, X = host.shape
        for k in split_steps(n, self.k_off):
            nxt = host.copy()  # ping-pong host buffers: halos need old values
            for i, cb in enumerate(plan.chunks):
                first, last = i == 0, i == plan.d - 1
                lo = 0 if first else cb.a - k * r
                hi = Y if last else cb.b + k * r
                full = jnp.asarray(host[lo:hi])
                stats.h2d_bytes += (hi - lo) * X * itemsize
                h = full.shape[0]
                for m in split_steps(k, self.k_on):
                    h = _account_fused(stats, st, h, X, m, first, last, itemsize)
                    full = self.fused_step(full, st.name, m, keep_top=first, keep_bottom=last)
                out_lo = 0 if first else cb.a
                nxt[cb.a : cb.b] = np.asarray(full[cb.a - out_lo : cb.b - out_lo])
                stats.d2h_bytes += cb.rows * X * itemsize
            host = nxt
        self._finalize(stats, x, st, n)
        return host, stats


class ResReu(_EngineBase):
    """Region sharing with intermediate-result reuse (Jin et al. [15]).

    Zero redundant transfer and zero redundant compute, at the price of
    single-step kernels interleaved with per-step buffer reads/writes —
    exactly the constraint the paper's Fig. 2b describes (k_on is ignored).

    Sliding-parallelogram formulation: chunk ``i``'s working band at step
    ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)`` (constant height).  Before
    each step the chunk reads *two shared regions* (2r rows at step ``s``)
    from the buffer and writes two for its successor — matching the paper's
    Fig. 2b description verbatim.
    """

    name = "resreu"

    def run(self, x, st, n):
        stats = TransferStats()
        r = st.radius
        plan = self._plan(x, st)
        if min(c.rows for c in plan.chunks) < 2 * r and plan.d > 1:
            raise ValueError("ResReu region sharing needs chunks of >= 2r rows")
        itemsize = x.dtype.itemsize
        host = np.asarray(x).copy()
        Y, X = host.shape
        for k in split_steps(n, self.k_off):
            carry = None  # carry[s]: 2r rows [b_i - 2r, b_i) + (k-s)r offset, step s
            for i, cb in enumerate(plan.chunks):
                first, last = i == 0, i == plan.d - 1
                # transfer: only rows no neighbour already holds
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                W = jnp.asarray(host[lo:hi])
                stats.h2d_bytes += (hi - lo) * X * itemsize
                new_carry = []
                for s in range(k):
                    if not last:
                        # write two shared regions (2r rows at step s)
                        new_carry.append(W[-2 * r :])
                        stats.buffer_bytes += 2 * r * X * itemsize  # write
                    if first:
                        inp = W  # covers [0, b0 + (k-s)r)
                    else:
                        # read two shared regions from the buffer
                        inp = jnp.concatenate([carry[s], W], axis=0)
                        stats.buffer_bytes += 2 * r * X * itemsize  # read
                    _account_fused(stats, st, inp.shape[0], X, 1, first, last, itemsize)
                    W = step_band(inp, st, keep_top=first, keep_bottom=last)
                carry = new_carry
                # W covers [0, b0) / [a_i, b_i) / [a_i, Y)
                off = cb.a if first else 0
                host[cb.a : cb.b] = np.asarray(W[off : off + cb.rows])
                stats.d2h_bytes += cb.rows * X * itemsize
        self._finalize(stats, x, st, n)
        return host, stats


class SO2DR(_EngineBase):
    """The paper's method (Alg. 1): off-chip region sharing at step 0 +
    deliberate redundant computation + uninterrupted k_on-step fused kernels.
    """

    name = "so2dr"

    def run(self, x, st, n):
        stats = TransferStats()
        r = st.radius
        plan = self._plan(x, st)
        itemsize = x.dtype.itemsize
        host = np.asarray(x).copy()
        Y, X = host.shape
        for k in split_steps(n, self.k_off):
            buffer = None  # rows [b_{i-1} - kr, b_{i-1} + kr) at step 0
            for i, cb in enumerate(plan.chunks):
                first, last = i == 0, i == plan.d - 1
                # transfer: everything the sharing buffer doesn't provide
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                h2d = jnp.asarray(host[lo:hi])
                stats.h2d_bytes += (hi - lo) * X * itemsize
                if first:
                    full = h2d
                    full_start = 0
                else:
                    full = jnp.concatenate([buffer, h2d], axis=0)
                    full_start = cb.a - k * r
                    stats.buffer_bytes += buffer.size * itemsize  # read
                if not last:
                    # line 6 of Alg. 1: write shared region for chunk i+1
                    sl = (cb.b - k * r) - full_start
                    buffer = full[sl : sl + 2 * k * r]
                    stats.buffer_bytes += buffer.size * itemsize  # write
                # lines 7-14: uninterrupted fused kernels, shrinking area
                h = full.shape[0]
                for m in split_steps(k, self.k_on):
                    h = _account_fused(stats, st, h, X, m, first, last, itemsize)
                    full = self.fused_step(full, st.name, m, keep_top=first, keep_bottom=last)
                # full covers [0, b0) / [a_i, b_i) / [a_i, Y)
                off = cb.a if first else 0
                host[cb.a : cb.b] = np.asarray(full[off : off + cb.rows])
                stats.d2h_bytes += cb.rows * X * itemsize
        self._finalize(stats, x, st, n)
        return host, stats


ENGINES = {e.name: e for e in (InCore, NaiveTB, ResReu, SO2DR)}


def get_engine(name: str, d: int, k_off: int, k_on: int, fused_step=None) -> _EngineBase:
    try:
        cls = ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; known: {sorted(ENGINES)}")
    return cls(d=d, k_off=k_off, k_on=k_on, fused_step=fused_step)
