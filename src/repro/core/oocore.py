"""Out-of-core stencil engines (the paper's Sec. II/IV, Alg. 1) as planners.

Five engines, all verified equivalent to the oracle
(:func:`repro.core.reference.run_reference`):

* :class:`InCore`   — whole domain resident on device, ``k_on``-step fused
  kernels (the paper's in-core comparison code, Sec. V-D).
* :class:`NaiveTB`  — temporal blocking with *redundant transfer*: every
  round each chunk re-transfers its ``k_off*r`` halos (paper Fig. 1b).
* :class:`ResReu`   — region sharing with intermediate-result reuse (Jin et
  al. [15]): zero redundant transfer, zero redundant compute, but kernels
  are forced to single time steps interleaved with buffer reads/writes
  (paper Fig. 2b).  Implemented as parallelogram band streaming: chunk ``i``'s
  working band at step ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)``; a
  per-step carry conveys rows ``[b_i-r, b_i+(k-s)r)`` to chunk ``i+1``.
* :class:`SO2DR`    — the paper's contribution: region sharing happens once
  per chunk-round at step 0 (rows ``[b_i-kr, b_i)``), redundant computation
  is deliberately admitted in the overlap wedges, and kernels run
  ``k_on`` fused steps uninterrupted (Alg. 1 lines 7-14).
* :class:`BoxTB`    — multi-axis temporal blocking on the box IR
  ("Beyond 16GB", arXiv 1709.02125): the domain splits into an N-D grid
  of tiles (``tiles[a]`` per axis), and each tile's H2D box grows a
  trapezoidal apron of ``t*r`` cells on every non-frame side so the tile
  advances ``t = k_off`` time steps per round trip — the off-chip analog
  of ``k_on``, generalizing :class:`NaiveTB` to 3-D workloads.

The classic streaming engines chunk along any single axis
(``chunk_axis``) of an N-D domain; their row arithmetic is unchanged —
it simply addresses ``shape[chunk_axis]`` instead of ``Y``.

Plan/execute split: each engine is a *planner* — :meth:`_EngineBase.compile`
turns ``(domain shape, stencil, n)`` into an
:class:`repro.core.plan.ExecutionPlan` (a typed transfer/kernel op
schedule), and any executor from :mod:`repro.core.executor` interprets it:
eagerly, software-pipelined (double-buffered), or as a zero-device dry run.
All :class:`TransferStats` accounting is derived from the plan itself.
``run()`` is the compile-then-eager-execute convenience that preserves the
historical engine API.
"""
from __future__ import annotations

import itertools
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .executor import EagerExecutor, FusedStep
from .plan import Box, ExecutionPlan, PlanBuilder, TransferStats
from .stencil import Stencil
from .tiling import ChunkPlan, make_chunk_plan, split_steps

__all__ = [
    "TransferStats", "InCore", "NaiveTB", "ResReu", "SO2DR", "BoxTB",
    "get_engine", "compile_plan", "compile_plan_nd", "compile_box_plan",
]


class _EngineBase:
    name: str = "base"

    def __init__(self, d: int, k_off: int, k_on: int,
                 fused_step: Optional[FusedStep] = None, codec=None,
                 policy=None, chunk_axis: int = 0):
        self.d = d
        self.k_off = k_off
        self.k_on = k_on
        self.fused_step = fused_step
        # transfer codec (name or repro.core.compress.Codec); None keeps
        # the schedule uncompressed.  Applied by the builder at build()
        # time, so planner subclasses stay codec-oblivious.
        self.codec = codec
        # kernel-dispatch policy (repro.kernels.dispatch.DispatchPolicy);
        # None = auto.  Only consulted when fused_step is not given.
        self.policy = policy
        # streaming axis: the classic engines decompose the domain into
        # d chunks along this axis (full extent on all others)
        self.chunk_axis = chunk_axis

    def _chunks(self, shape: Sequence[int], st: Stencil) -> ChunkPlan:
        L = shape[self.chunk_axis]
        cross = math.prod(shape) // max(L, 1)
        plan = make_chunk_plan(L, cross, st.radius, self.d)
        if self.k_off > plan.max_k_off():
            raise ValueError(
                f"k_off={self.k_off} violates region-sharing feasibility "
                f"(k_off*r must fit in the smallest chunk; max {plan.max_k_off()})"
            )
        return plan

    def _builder(self, shape: Sequence[int], st: Stencil, n: int,
                 itemsize: int) -> PlanBuilder:
        b = PlanBuilder(self.name, st, shape, n, self.d, self.k_off,
                        self.k_on, itemsize, chunk_axis=self.chunk_axis)
        if self.codec is not None:
            b.with_compression(self.codec)
        return b

    def compile_nd(self, shape: Sequence[int], st: Stencil, n: int,
                   itemsize: int = 4) -> ExecutionPlan:
        """Compile the engine's schedule for an N-D framed domain —
        geometry only, no arrays touched."""
        raise NotImplementedError

    def compile(self, Y: int, X: int, st: Stencil, n: int,
                itemsize: int = 4) -> ExecutionPlan:
        """2-D convenience wrapper around :meth:`compile_nd`."""
        return self.compile_nd((Y, X), st, n, itemsize=itemsize)

    def run(self, x: np.ndarray, st: Stencil, n: int) -> Tuple[np.ndarray, TransferStats]:
        """Compile + eager execution (the historical engine API)."""
        plan = self.compile_nd(x.shape, st, n, itemsize=x.dtype.itemsize)
        return EagerExecutor(self.fused_step, policy=self.policy).execute(plan, x)


class InCore(_EngineBase):
    """Whole domain on device; the paper's in-core code with k_on-step kernels."""

    name = "incore"

    def compile_nd(self, shape, st, n, itemsize=4):
        L = shape[self.chunk_axis]
        b = self._builder(shape, st, n, itemsize)
        b.h2d("band", 0, L, rnd=0, chunk=0)
        for m in split_steps(n, self.k_on):
            b.fused_kernel("band", m, keep_top=True, keep_bottom=True,
                           rnd=0, chunk=0)
        b.d2h("band", 0, L, 0, L, rnd=0, chunk=0)
        b.commit(rnd=0)
        return b.build()


class NaiveTB(_EngineBase):
    """Temporal blocking with redundant halo transfer (paper Fig. 1b).

    The per-round :class:`HostCommit` barrier realises the ping-pong host
    buffer: within a round every chunk's H2D reads pre-round halo rows."""

    name = "naive_tb"

    def compile_nd(self, shape, st, n, itemsize=4):
        r = st.radius
        L = shape[self.chunk_axis]
        chunks = self._chunks(shape, st)
        b = self._builder(shape, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                lo = 0 if first else cb.a - k * r
                hi = L if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                for m in split_steps(k, self.k_on):
                    b.fused_kernel(reg, m, first, last, rnd, i)
                out_lo = 0 if first else cb.a
                b.d2h(reg, cb.a - out_lo, cb.b - out_lo, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


class ResReu(_EngineBase):
    """Region sharing with intermediate-result reuse (Jin et al. [15]).

    Zero redundant transfer and zero redundant compute, at the price of
    single-step kernels interleaved with per-step buffer reads/writes —
    exactly the constraint the paper's Fig. 2b describes (k_on is ignored).

    Sliding-parallelogram formulation: chunk ``i``'s working band at step
    ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)`` (constant height).  Before
    each step the chunk writes two shared regions (2r rows at step ``s``)
    into per-step carry buffers for its successor and reads the
    predecessor's pair — matching the paper's Fig. 2b description verbatim.
    """

    name = "resreu"

    def compile_nd(self, shape, st, n, itemsize=4):
        r = st.radius
        L = shape[self.chunk_axis]
        chunks = self._chunks(shape, st)
        if min(c.rows for c in chunks.chunks) < 2 * r and chunks.d > 1:
            raise ValueError("ResReu region sharing needs chunks of >= 2r rows")
        b = self._builder(shape, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                lo = 0 if first else cb.a + k * r
                hi = L if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                for s in range(k):
                    if not last:
                        # write the shared-region pair for chunk i+1
                        h = b.height(reg)
                        b.buffer_write(f"carry:r{rnd}c{i}s{s}", reg,
                                       h - 2 * r, h, rnd, i)
                    if not first:
                        # read the predecessor's pair
                        b.buffer_read(reg, f"carry:r{rnd}c{i - 1}s{s}", reg,
                                      rnd, i)
                    b.fused_kernel(reg, 1, first, last, rnd, i)
                # band covers [0, b0) / [a_i, b_i) / [a_i, L)
                off = cb.a if first else 0
                b.d2h(reg, off, off + cb.rows, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


class SO2DR(_EngineBase):
    """The paper's method (Alg. 1): off-chip region sharing at step 0 +
    deliberate redundant computation + uninterrupted k_on-step fused kernels.
    """

    name = "so2dr"

    def compile_nd(self, shape, st, n, itemsize=4):
        r = st.radius
        L = shape[self.chunk_axis]
        chunks = self._chunks(shape, st)
        b = self._builder(shape, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                # transfer: everything the sharing buffer doesn't provide
                lo = 0 if first else cb.a + k * r
                hi = L if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                if first:
                    full_start = 0
                else:
                    b.buffer_read(reg, f"share:r{rnd}c{i - 1}", reg, rnd, i)
                    full_start = cb.a - k * r
                if not last:
                    # line 6 of Alg. 1: rows [b_i - kr, b_i + kr) for chunk i+1
                    sl = (cb.b - k * r) - full_start
                    b.buffer_write(f"share:r{rnd}c{i}", reg, sl,
                                   sl + 2 * k * r, rnd, i)
                # lines 7-14: uninterrupted fused kernels, shrinking area
                for m in split_steps(k, self.k_on):
                    b.fused_kernel(reg, m, first, last, rnd, i)
                # band covers [0, b0) / [a_i, b_i) / [a_i, L)
                off = cb.a if first else 0
                b.d2h(reg, off, off + cb.rows, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


class BoxTB(_EngineBase):
    """Multi-axis temporal blocking on the box IR (arXiv 1709.02125).

    The domain splits into an N-D tile grid: ``tiles[a]`` near-even tiles
    of the interior along axis ``a`` (axes beyond ``len(tiles)`` stay
    whole).  Each round advances ``t = k_off`` time steps: a tile's H2D
    box is its owned interior grown by a ``t*r``-cell apron on every side
    that is not a domain frame — the trapezoid whose redundant apron
    compute is the price of ``t`` steps per host round trip.  On-chip,
    the ``t`` steps run as ``k_on``-step fused kernels (the paper's
    synergy, now per tile); D2H writes back only the owned interior box.

    A 1-tile-per-axis grid degenerates to :class:`InCore`-style whole-
    domain rounds; ``tiles=(d,)`` on a 2-D domain is :class:`NaiveTB`
    chunking with box d2h.  ``plan.d`` is the total tile count and
    ``plan.k_off`` the time depth ``t``."""

    name = "box_tb"

    def __init__(self, d: int = 0, k_off: int = 1, k_on: int = 1,
                 fused_step: Optional[FusedStep] = None, codec=None,
                 policy=None, chunk_axis: int = 0,
                 tiles: Sequence[int] = ()):
        tiles = tuple(int(t) for t in tiles) or ((d,) if d else (1,))
        if any(t < 1 for t in tiles):
            raise ValueError(f"tile counts must be >= 1, got {tiles}")
        super().__init__(math.prod(tiles), k_off, k_on, fused_step,
                         codec, policy, chunk_axis)
        self.tiles = tiles

    def _builder(self, shape, st, n, itemsize):
        b = super()._builder(shape, st, n, itemsize)
        b.tiles = self.tiles
        return b

    def compile_nd(self, shape, st, n, itemsize=4):
        r = st.radius
        nd = len(shape)
        tiles = self.tiles + (1,) * (nd - len(self.tiles))
        if len(tiles) != nd:
            raise ValueError(
                f"tiles {self.tiles} over-ranks domain shape {tuple(shape)}")
        # per-axis interior splits (same near-even arithmetic as the
        # 1-axis chunk plan), plus the NaiveTB feasibility rule per axis:
        # the t*r apron must fit inside the smallest neighbouring tile
        splits = []
        for a in range(nd):
            cp = make_chunk_plan(shape[a], math.prod(shape) // shape[a],
                                 r, tiles[a])
            if tiles[a] > 1 and self.k_off > cp.max_k_off():
                raise ValueError(
                    f"time depth t={self.k_off} infeasible along axis {a}: "
                    f"t*r must fit in the smallest tile (max {cp.max_k_off()})")
            splits.append(cp.chunks)
        b = self._builder(shape, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for idx, multi in enumerate(itertools.product(
                    *(range(t) for t in tiles))):
                own = [splits[a][multi[a]] for a in range(nd)]
                keep_lo = tuple(multi[a] == 0 for a in range(nd))
                keep_hi = tuple(multi[a] == tiles[a] - 1 for a in range(nd))
                in_box = Box(
                    tuple(0 if keep_lo[a] else own[a].a - k * r
                          for a in range(nd)),
                    tuple(shape[a] if keep_hi[a] else own[a].b + k * r
                          for a in range(nd)))
                reg = f"band:r{rnd}t{idx}"
                b.h2d_box(reg, in_box, rnd, idx)
                for m in split_steps(k, self.k_on):
                    b.fused_kernel_box(reg, m, keep_lo, keep_hi, rnd, idx)
                b.d2h_box(reg, Box(tuple(c.a for c in own),
                                   tuple(c.b for c in own)), rnd, idx)
            b.commit(rnd)
        return b.build()


ENGINES = {e.name: e for e in (InCore, NaiveTB, ResReu, SO2DR, BoxTB)}


def get_engine(name: str, d: int, k_off: int, k_on: int, fused_step=None,
               codec=None, policy=None, chunk_axis: int = 0,
               tiles: Sequence[int] = ()) -> _EngineBase:
    try:
        cls = ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; known: {sorted(ENGINES)}")
    kwargs = dict(d=d, k_off=k_off, k_on=k_on, fused_step=fused_step,
                  codec=codec, policy=policy, chunk_axis=chunk_axis)
    if cls is BoxTB:
        kwargs["tiles"] = tiles
    elif tiles:
        raise ValueError(f"engine {name!r} does not take a tile grid; "
                         f"tiles= is box_tb-only")
    return cls(**kwargs)


def compile_plan_nd(engine: str, st: Stencil, shape: Sequence[int], n: int,
                    d: int, k_off: int, k_on: int, itemsize: int = 4,
                    codec=None, chunk_axis: int = 0,
                    tiles: Sequence[int] = ()) -> ExecutionPlan:
    """Compile one engine configuration for an N-D framed domain — the
    geometry-only entry point used by accounting and the autotuner.
    ``codec`` (a name from :data:`repro.core.compress.CODECS` or a codec
    instance) wraps every transfer in Compress/Decompress ops."""
    return get_engine(engine, d=d, k_off=k_off, k_on=k_on, codec=codec,
                      chunk_axis=chunk_axis, tiles=tiles).compile_nd(
        shape, st, n, itemsize=itemsize)


def compile_plan(engine: str, st: Stencil, Y: int, X: int, n: int,
                 d: int, k_off: int, k_on: int, itemsize: int = 4,
                 codec=None, chunk_axis: int = 0) -> ExecutionPlan:
    """2-D convenience wrapper around :func:`compile_plan_nd`."""
    return compile_plan_nd(engine, st, (Y, X), n, d, k_off, k_on,
                           itemsize=itemsize, codec=codec,
                           chunk_axis=chunk_axis)


def compile_box_plan(st: Stencil, shape: Sequence[int], n: int,
                     tiles: Sequence[int], time_depth: int, k_on: int = 1,
                     itemsize: int = 4, codec=None) -> ExecutionPlan:
    """Compile a :class:`BoxTB` temporal-blocking plan: ``tiles[a]`` tiles
    per axis, ``time_depth`` steps per H2D round trip, ``k_on``-step fused
    kernels on chip."""
    return get_engine("box_tb", d=0, k_off=time_depth, k_on=k_on,
                      codec=codec, tiles=tiles).compile_nd(
        shape, st, n, itemsize=itemsize)
