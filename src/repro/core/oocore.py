"""Out-of-core stencil engines (the paper's Sec. II/IV, Alg. 1) as planners.

Four engines, all verified equivalent to the oracle
(:func:`repro.core.reference.run_reference`):

* :class:`InCore`   — whole domain resident on device, ``k_on``-step fused
  kernels (the paper's in-core comparison code, Sec. V-D).
* :class:`NaiveTB`  — temporal blocking with *redundant transfer*: every
  round each chunk re-transfers its ``k_off*r`` halos (paper Fig. 1b).
* :class:`ResReu`   — region sharing with intermediate-result reuse (Jin et
  al. [15]): zero redundant transfer, zero redundant compute, but kernels
  are forced to single time steps interleaved with buffer reads/writes
  (paper Fig. 2b).  Implemented as parallelogram band streaming: chunk ``i``'s
  working band at step ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)``; a
  per-step carry conveys rows ``[b_i-r, b_i+(k-s)r)`` to chunk ``i+1``.
* :class:`SO2DR`    — the paper's contribution: region sharing happens once
  per chunk-round at step 0 (rows ``[b_i-kr, b_i)``), redundant computation
  is deliberately admitted in the overlap wedges, and kernels run
  ``k_on`` fused steps uninterrupted (Alg. 1 lines 7-14).

Plan/execute split: each engine is a *planner* — :meth:`_EngineBase.compile`
turns ``(domain shape, stencil, n)`` into an
:class:`repro.core.plan.ExecutionPlan` (a typed transfer/kernel op
schedule), and any executor from :mod:`repro.core.executor` interprets it:
eagerly, software-pipelined (double-buffered), or as a zero-device dry run.
All :class:`TransferStats` accounting is derived from the plan itself.
``run()`` is the compile-then-eager-execute convenience that preserves the
historical engine API.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .executor import EagerExecutor, FusedStep
from .plan import ExecutionPlan, PlanBuilder, TransferStats
from .stencil import Stencil
from .tiling import ChunkPlan, make_chunk_plan, split_steps

__all__ = [
    "TransferStats", "InCore", "NaiveTB", "ResReu", "SO2DR",
    "get_engine", "compile_plan",
]


class _EngineBase:
    name: str = "base"

    def __init__(self, d: int, k_off: int, k_on: int,
                 fused_step: Optional[FusedStep] = None, codec=None,
                 policy=None):
        self.d = d
        self.k_off = k_off
        self.k_on = k_on
        self.fused_step = fused_step
        # transfer codec (name or repro.core.compress.Codec); None keeps
        # the schedule uncompressed.  Applied by the builder at build()
        # time, so planner subclasses stay codec-oblivious.
        self.codec = codec
        # kernel-dispatch policy (repro.kernels.dispatch.DispatchPolicy);
        # None = auto.  Only consulted when fused_step is not given.
        self.policy = policy

    def _chunks(self, Y: int, X: int, st: Stencil) -> ChunkPlan:
        plan = make_chunk_plan(Y, X, st.radius, self.d)
        if self.k_off > plan.max_k_off():
            raise ValueError(
                f"k_off={self.k_off} violates region-sharing feasibility "
                f"(k_off*r must fit in the smallest chunk; max {plan.max_k_off()})"
            )
        return plan

    def _builder(self, Y: int, X: int, st: Stencil, n: int, itemsize: int) -> PlanBuilder:
        b = PlanBuilder(self.name, st, Y, X, n, self.d, self.k_off,
                        self.k_on, itemsize)
        if self.codec is not None:
            b.with_compression(self.codec)
        return b

    def compile(self, Y: int, X: int, st: Stencil, n: int,
                itemsize: int = 4) -> ExecutionPlan:
        """Compile the engine's schedule for a (Y, X) framed domain —
        geometry only, no arrays touched."""
        raise NotImplementedError

    def run(self, x: np.ndarray, st: Stencil, n: int) -> Tuple[np.ndarray, TransferStats]:
        """Compile + eager execution (the historical engine API)."""
        plan = self.compile(x.shape[0], x.shape[1], st, n,
                            itemsize=x.dtype.itemsize)
        return EagerExecutor(self.fused_step, policy=self.policy).execute(plan, x)


class InCore(_EngineBase):
    """Whole domain on device; the paper's in-core code with k_on-step kernels."""

    name = "incore"

    def compile(self, Y, X, st, n, itemsize=4):
        b = self._builder(Y, X, st, n, itemsize)
        b.h2d("band", 0, Y, rnd=0, chunk=0)
        for m in split_steps(n, self.k_on):
            b.fused_kernel("band", m, keep_top=True, keep_bottom=True,
                           rnd=0, chunk=0)
        b.d2h("band", 0, Y, 0, Y, rnd=0, chunk=0)
        b.commit(rnd=0)
        return b.build()


class NaiveTB(_EngineBase):
    """Temporal blocking with redundant halo transfer (paper Fig. 1b).

    The per-round :class:`HostCommit` barrier realises the ping-pong host
    buffer: within a round every chunk's H2D reads pre-round halo rows."""

    name = "naive_tb"

    def compile(self, Y, X, st, n, itemsize=4):
        r = st.radius
        chunks = self._chunks(Y, X, st)
        b = self._builder(Y, X, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                lo = 0 if first else cb.a - k * r
                hi = Y if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                for m in split_steps(k, self.k_on):
                    b.fused_kernel(reg, m, first, last, rnd, i)
                out_lo = 0 if first else cb.a
                b.d2h(reg, cb.a - out_lo, cb.b - out_lo, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


class ResReu(_EngineBase):
    """Region sharing with intermediate-result reuse (Jin et al. [15]).

    Zero redundant transfer and zero redundant compute, at the price of
    single-step kernels interleaved with per-step buffer reads/writes —
    exactly the constraint the paper's Fig. 2b describes (k_on is ignored).

    Sliding-parallelogram formulation: chunk ``i``'s working band at step
    ``s`` covers rows ``[a_i+(k-s)r, b_i+(k-s)r)`` (constant height).  Before
    each step the chunk writes two shared regions (2r rows at step ``s``)
    into per-step carry buffers for its successor and reads the
    predecessor's pair — matching the paper's Fig. 2b description verbatim.
    """

    name = "resreu"

    def compile(self, Y, X, st, n, itemsize=4):
        r = st.radius
        chunks = self._chunks(Y, X, st)
        if min(c.rows for c in chunks.chunks) < 2 * r and chunks.d > 1:
            raise ValueError("ResReu region sharing needs chunks of >= 2r rows")
        b = self._builder(Y, X, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                for s in range(k):
                    if not last:
                        # write the shared-region pair for chunk i+1
                        h = b.height(reg)
                        b.buffer_write(f"carry:r{rnd}c{i}s{s}", reg,
                                       h - 2 * r, h, rnd, i)
                    if not first:
                        # read the predecessor's pair
                        b.buffer_read(reg, f"carry:r{rnd}c{i - 1}s{s}", reg,
                                      rnd, i)
                    b.fused_kernel(reg, 1, first, last, rnd, i)
                # band covers [0, b0) / [a_i, b_i) / [a_i, Y)
                off = cb.a if first else 0
                b.d2h(reg, off, off + cb.rows, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


class SO2DR(_EngineBase):
    """The paper's method (Alg. 1): off-chip region sharing at step 0 +
    deliberate redundant computation + uninterrupted k_on-step fused kernels.
    """

    name = "so2dr"

    def compile(self, Y, X, st, n, itemsize=4):
        r = st.radius
        chunks = self._chunks(Y, X, st)
        b = self._builder(Y, X, st, n, itemsize)
        for rnd, k in enumerate(split_steps(n, self.k_off)):
            for i, cb in enumerate(chunks.chunks):
                first, last = i == 0, i == chunks.d - 1
                reg = f"band:r{rnd}c{i}"
                # transfer: everything the sharing buffer doesn't provide
                lo = 0 if first else cb.a + k * r
                hi = Y if last else cb.b + k * r
                b.h2d(reg, lo, hi, rnd, i)
                if first:
                    full_start = 0
                else:
                    b.buffer_read(reg, f"share:r{rnd}c{i - 1}", reg, rnd, i)
                    full_start = cb.a - k * r
                if not last:
                    # line 6 of Alg. 1: rows [b_i - kr, b_i + kr) for chunk i+1
                    sl = (cb.b - k * r) - full_start
                    b.buffer_write(f"share:r{rnd}c{i}", reg, sl,
                                   sl + 2 * k * r, rnd, i)
                # lines 7-14: uninterrupted fused kernels, shrinking area
                for m in split_steps(k, self.k_on):
                    b.fused_kernel(reg, m, first, last, rnd, i)
                # band covers [0, b0) / [a_i, b_i) / [a_i, Y)
                off = cb.a if first else 0
                b.d2h(reg, off, off + cb.rows, cb.a, cb.b, rnd, i)
            b.commit(rnd)
        return b.build()


ENGINES = {e.name: e for e in (InCore, NaiveTB, ResReu, SO2DR)}


def get_engine(name: str, d: int, k_off: int, k_on: int, fused_step=None,
               codec=None, policy=None) -> _EngineBase:
    try:
        cls = ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; known: {sorted(ENGINES)}")
    return cls(d=d, k_off=k_off, k_on=k_on, fused_step=fused_step, codec=codec,
               policy=policy)


def compile_plan(engine: str, st: Stencil, Y: int, X: int, n: int,
                 d: int, k_off: int, k_on: int, itemsize: int = 4,
                 codec=None) -> ExecutionPlan:
    """Compile one engine configuration into its op schedule — the
    geometry-only entry point used by accounting and the autotuner.
    ``codec`` (a name from :data:`repro.core.compress.CODECS` or a codec
    instance) wraps every transfer in Compress/Decompress ops."""
    return get_engine(engine, d=d, k_off=k_off, k_on=k_on, codec=codec).compile(
        Y, X, st, n, itemsize=itemsize)
