"""One tuner entry point: ``tune(spec, profile=..., budget=...)``.

The tuner surface had diverged three ways — :func:`~repro.core.autotune.
autotune` (row plans, interior size int), ``autotune_box`` (N-D framed
shape), ``autotune_sharded`` (framed side int + device count) — each
with its own result record and argument spelling.  This module redesigns
that surface around two types:

* :class:`TuneSpec` — what to tune: framed domain shape, stencil, step
  count, optional mesh, and the candidate domains (engine/codec/impl
  grids).  One spec subsumes all three old call signatures; the mode is
  inferred (``mesh`` set -> sharded, non-2-D shape or a ``box_tb``
  engine -> box, else row).
* :class:`TuneResult` — one ranked candidate, spelled identically for
  every mode: a unified ``config`` dict, the modeled time, and — when
  measured refinement ran — the measured time, the model-vs-measured
  error, and the id of the :class:`~repro.core.calibrate.DeviceProfile`
  that priced it.

``tune`` ranks the candidate set on dry-run plans exactly like the old
sweeps (the old functions survive as deprecated wrappers over the same
internals, so rankings are identical by construction), then optionally
*refines* the top ``budget`` candidates with short measured runs on
bucketed small domains: **model proposes, hardware disposes**.  A
candidate is only promoted over the modeled incumbent when its measured
time is no worse than the incumbent's measured time — property-tested in
``tests/test_tune.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple, Union

from .analytic import EngineTimes, Hardware, model_times
from .autotune import (
    BoxChoice, Choice, ShardedChoice,
    _autotune, _autotune_box, _autotune_sharded,
)
from .calibrate import DeviceProfile, resolve_hardware
from .lower import ExecStats

__all__ = ["TuneSpec", "TuneResult", "tune"]


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """What to tune, in one spelling for every plan family.

    ``shape`` is always the *framed* domain — an int means a square.
    ``mesh`` switches to the sharded (L2) sweep: an int sweeps every
    ``(rows, cols)`` factorization of that many devices, a tuple pins
    the decomposition.  The grid fields are candidate *domains*; modes
    ignore the grids that do not apply to them (a box sweep reads
    ``box_tile_grid``/``time_depth_grid``, a sharded sweep reads
    ``k_ici_grid`` plus ``codecs`` for the halo wire, the row sweep
    reads the rest)."""

    stencil: str
    shape: Union[int, Tuple[int, ...]]
    steps: int
    mesh: Optional[Union[int, Tuple[int, int]]] = None
    engines: Tuple[str, ...] = ("so2dr", "resreu")
    d_grid: Tuple[int, ...] = (4, 8, 16)
    s_tb_grid: Tuple[int, ...] = (20, 40, 80, 160, 320, 640)
    k_on_grid: Tuple[int, ...] = (1, 2, 4, 8)
    codecs: Tuple[str, ...] = ("identity", "zrle")
    kernel_impls: Tuple[str, ...] = ("reference", "pallas", "pallas_db")
    tile_grid: Tuple[Optional[tuple], ...] = (None,)
    box_tile_grid: Tuple[Tuple[int, ...], ...] = ((1, 1), (2, 2), (4, 4))
    time_depth_grid: Tuple[int, ...] = (1, 2, 4)
    k_ici_grid: Tuple[int, ...] = (1, 2, 4, 8)
    b_elem: int = 4

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        shape = self.framed_shape
        if not shape or any(s < 1 for s in shape):
            raise ValueError(f"bad framed shape {shape}")
        if isinstance(self.mesh, tuple) and (
                len(self.mesh) != 2 or any(m < 1 for m in self.mesh)):
            raise ValueError(f"mesh must be (rows, cols), got {self.mesh}")

    @property
    def framed_shape(self) -> Tuple[int, ...]:
        if isinstance(self.shape, int):
            return (self.shape, self.shape)
        return tuple(int(s) for s in self.shape)

    @property
    def n_devices(self) -> Optional[int]:
        if self.mesh is None:
            return None
        return self.mesh if isinstance(self.mesh, int) else math.prod(self.mesh)

    @property
    def mode(self) -> str:
        if self.mesh is not None:
            return "sharded"
        if len(self.framed_shape) != 2 or "box_tb" in self.engines:
            return "box"
        return "row"


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """One ranked candidate, spelled identically for every mode.

    ``config`` always carries ``engine`` plus that engine family's knobs
    (``d``/``s_tb``/``k_on``/``codec``/``kernel_impl``/``tile`` for row
    plans, ``tiles``/``time_depth`` for box plans, ``mesh``/``k_ici``
    for sharded plans).  ``measured_s``/``model_error``/``exec_stats``
    are populated only for candidates the refinement pass actually ran;
    ``model_error`` is ``(modeled - measured) / measured`` on the same
    small domain, also mirrored into ``exec_stats.model_error``."""

    mode: str
    engine: str
    config: Dict[str, object]
    modeled_s: float
    bottleneck: str
    times: Optional[EngineTimes] = None
    measured_s: Optional[float] = None
    model_error: Optional[float] = None
    profile_id: Optional[str] = None
    exec_stats: Optional[ExecStats] = None
    extras: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_record(self) -> Dict[str, object]:
        """JSON-safe benchmark row — the one spelling replacing the
        three per-mode row formats the old sweeps emitted."""
        config = {k: (list(v) if isinstance(v, tuple) else v)
                  for k, v in self.config.items()}
        rec: Dict[str, object] = {
            "mode": self.mode,
            "engine": self.engine,
            "config": config,
            "modeled_s": self.modeled_s,
            "bottleneck": self.bottleneck,
            "measured_s": self.measured_s,
            "model_error": self.model_error,
            "profile_id": self.profile_id,
        }
        rec.update(self.extras)
        return rec


def _from_choice(c: Choice, pid: Optional[str]) -> TuneResult:
    return TuneResult(
        mode="row", engine=c.engine,
        config=dict(engine=c.engine, d=c.d, s_tb=c.s_tb, k_on=c.k_on,
                    codec=c.codec, kernel_impl=c.kernel_impl, tile=c.tile),
        modeled_s=c.time_s, bottleneck=c.bottleneck, times=c.times,
        profile_id=pid)


def _from_box(c: BoxChoice, pid: Optional[str]) -> TuneResult:
    return TuneResult(
        mode="box", engine="box_tb",
        config=dict(engine="box_tb", tiles=c.tiles, time_depth=c.time_depth,
                    k_on=c.k_on, codec=c.codec),
        modeled_s=c.time_s, bottleneck=c.bottleneck, times=c.times,
        profile_id=pid,
        extras=dict(redundant_elements=c.redundant_elements,
                    redundancy=c.redundancy))


def _from_sharded(c: ShardedChoice, pid: Optional[str]) -> TuneResult:
    return TuneResult(
        mode="sharded", engine="sharded",
        config=dict(engine="sharded", mesh=c.mesh, k_ici=c.k_ici,
                    codec=c.codec),
        modeled_s=c.time_s, bottleneck=c.bottleneck, profile_id=pid,
        extras=dict(ici_s=c.ici_s, kernel_s=c.kernel_s,
                    ici_bytes=c.ici_bytes, ici_wire_bytes=c.ici_wire_bytes,
                    redundancy=c.redundancy))


# ------------------------------------------------------- measured runs

# interior-size buckets for refinement runs: candidates measure on the
# smallest bucket their geometry compiles at, so repeated (impl, shape)
# signatures share compiled kernels across candidates
_SMALL_INTERIORS = (64, 96, 128, 192, 256)
_SMALL_STEPS = 8


def _measure_row(spec: TuneSpec, res: TuneResult, hw: Hardware, profile):
    """Short measured run of one row-plan candidate on a bucketed small
    domain.  Returns ``(measured_s, modeled_small_s, exec_stats)`` or
    ``None`` when no bucket admits the candidate's geometry."""
    import numpy as np

    from repro.core.executor import get_executor
    from repro.core.oocore import compile_plan
    from repro.core.stencil import get_stencil
    from repro.kernels.dispatch import DispatchPolicy, modeled_kernel_time

    st = get_stencil(spec.stencil)
    cfg = res.config
    steps = min(spec.steps, _SMALL_STEPS)
    s_tb = min(cfg["s_tb"], steps)
    plan = None
    for sz in _SMALL_INTERIORS:
        Y = X = sz + 2 * st.radius
        try:
            plan = compile_plan(
                cfg["engine"], st, Y, X, steps, cfg["d"], s_tb,
                cfg["k_on"], itemsize=spec.b_elem,
                codec=None if cfg["codec"] == "identity" else cfg["codec"])
            break
        except ValueError:
            plan = None
    if plan is None:
        return None
    policy = DispatchPolicy(impl=cfg["kernel_impl"], tile=cfg["tile"])
    ex = get_executor("eager", policy=policy)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(plan.shape).astype(np.float32)
    ex.execute(plan, x)                    # warmup: compile + trace
    _, stats = ex.execute(plan, x)
    exec_stats = ex.exec_stats
    t = model_times(stats, hw)
    kt = modeled_kernel_time(plan, hw, cfg["kernel_impl"], cfg["tile"],
                             profile=profile)
    if kt is not None:
        t = dataclasses.replace(t, kernel=kt[0], kernel_mem=kt[1],
                                kernel_compute=kt[2])
    return exec_stats.wall_s, t.total_overlapped(hw.n_streams), exec_stats


def _measure_box(spec: TuneSpec, res: TuneResult, hw: Hardware, profile):
    """Short measured run of one BoxTB candidate on a scaled-down box."""
    import numpy as np

    from repro.core.executor import get_executor
    from repro.core.oocore import compile_box_plan
    from repro.core.stencil import get_stencil

    st = get_stencil(spec.stencil)
    cfg = res.config
    steps = min(spec.steps, 2 * cfg["time_depth"])
    plan = None
    for interior in (64, 128):
        shape = tuple(min(s, interior + 2 * st.radius)
                      for s in spec.framed_shape)
        try:
            plan = compile_box_plan(st, shape, steps, cfg["tiles"],
                                    cfg["time_depth"], k_on=cfg["k_on"],
                                    itemsize=spec.b_elem,
                                    codec=None if cfg["codec"] == "identity"
                                    else cfg["codec"])
            break
        except ValueError:
            plan = None
    if plan is None:
        return None
    ex = get_executor("eager")
    rng = np.random.default_rng(0)
    x = rng.standard_normal(plan.shape).astype(np.float32)
    ex.execute(plan, x)
    _, stats = ex.execute(plan, x)
    exec_stats = ex.exec_stats
    t = model_times(stats, hw)
    return exec_stats.wall_s, t.total_overlapped(hw.n_streams), exec_stats


def _default_measure(hw: Hardware, profile) -> Callable:
    def measure(spec: TuneSpec, res: TuneResult):
        if res.mode == "row":
            return _measure_row(spec, res, hw, profile)
        if res.mode == "box":
            return _measure_box(spec, res, hw, profile)
        return None   # sharded refinement needs a real mesh; stay modeled
    return measure


def _attach(res: TuneResult, measured) -> TuneResult:
    if measured is None:
        return res
    measured_s, modeled_small, exec_stats = measured
    err = (modeled_small - measured_s) / max(measured_s, 1e-12)
    if exec_stats is not None:
        exec_stats.modeled_s = modeled_small
        exec_stats.model_error = err
    return dataclasses.replace(res, measured_s=measured_s, model_error=err,
                               exec_stats=exec_stats)


def _refine(ranked: List[TuneResult], spec: TuneSpec, budget: int,
            measure: Callable) -> List[TuneResult]:
    """Measure the top ``budget`` candidates and re-rank.

    Invariant (property-tested): a candidate outranks the modeled
    incumbent only when its measured time is <= the incumbent's measured
    time.  If the incumbent itself could not be measured, the modeled
    order stands — refinement refuses to promote on one-sided
    evidence."""
    k = min(budget, len(ranked))
    head = [_attach(r, measure(spec, r)) for r in ranked[:k]]
    tail = ranked[k:]
    if not head or head[0].measured_s is None:
        return head + tail
    measured = sorted((r for r in head if r.measured_s is not None),
                      key=lambda r: r.measured_s)
    unmeasured = [r for r in head if r.measured_s is None]
    return measured + unmeasured + tail


def tune(spec: TuneSpec,
         profile: Optional[Union[DeviceProfile, str]] = None,
         budget: int = 0,
         hw: Optional[Hardware] = None,
         measure: Optional[Callable] = None) -> List[TuneResult]:
    """Rank every feasible configuration of ``spec`` (best first).

    ``profile`` — a :class:`~repro.core.calibrate.DeviceProfile` (or a
    path to one): its fitted constants replace the hand-entered
    ``Hardware`` everywhere the model prices this sweep, its per-impl
    kernel terms feed :func:`~repro.kernels.dispatch.
    modeled_kernel_time`, and its id is stamped on every result.
    ``hw`` overrides the profile's generic constants when both are
    given (the profile still contributes kernel terms + id).

    ``budget`` — how many of the top modeled candidates to *measure*
    with short runs on bucketed small domains; the measured candidates
    re-rank by wall clock, with per-candidate model-vs-measured error
    in ``TuneResult.model_error`` / ``exec_stats.model_error``.  0
    keeps the ranking purely modeled.  ``measure`` injects a custom
    measurement callable (tests)."""
    from repro.core.stencil import get_stencil

    if isinstance(profile, str):
        profile = DeviceProfile.load(profile)
    hw_res = hw if hw is not None else resolve_hardware(profile)
    pid = profile.profile_id if profile is not None else None
    st = get_stencil(spec.stencil)
    mode = spec.mode
    shape = spec.framed_shape

    if mode == "row":
        if shape[0] != shape[1]:
            raise ValueError(
                f"row-mode tuning needs a square framed domain, got "
                f"{shape}; pass engines=('box_tb',) for rectangles")
        sz = shape[0] - 2 * st.radius
        choices = _autotune(
            st, sz, spec.steps, hw_res, engines=spec.engines,
            d_grid=spec.d_grid, s_tb_grid=spec.s_tb_grid,
            k_on_grid=spec.k_on_grid, codecs=spec.codecs,
            kernel_impls=spec.kernel_impls, tile_grid=spec.tile_grid,
            b_elem=spec.b_elem, profile=profile)
        ranked = [_from_choice(c, pid) for c in choices]
    elif mode == "box":
        choices = _autotune_box(
            st, shape, spec.steps, hw_res, tile_grid=spec.box_tile_grid,
            time_depth_grid=spec.time_depth_grid,
            k_on_grid=spec.k_on_grid, codecs=spec.codecs,
            b_elem=spec.b_elem)
        ranked = [_from_box(c, pid) for c in choices]
    else:
        choices = _autotune_sharded(
            st, shape[0], spec.steps, hw_res, n_devices=spec.n_devices,
            k_ici_grid=spec.k_ici_grid, codecs=spec.codecs,
            b_elem=spec.b_elem)
        if isinstance(spec.mesh, tuple):
            choices = [c for c in choices if c.mesh == spec.mesh]
        ranked = [_from_sharded(c, pid) for c in choices]

    if budget > 0 and ranked:
        measure = measure or _default_measure(hw_res, profile)
        ranked = _refine(ranked, spec, budget, measure)
    return ranked
