"""Analytic performance model (paper Sec. III) with hardware constants.

The paper's bottleneck model::

    T_tot ∝ max( D_chk / BW_intc,
                 (D_chk + W_halo * S_TB) / BW_dmem * S_TB )

generalizes per engine via :class:`TransferStats` produced by the engines in
:mod:`repro.core.oocore`.  Because this container is CPU-only, kernel-phase
*wall* times on the TPU target are modeled, not measured; benchmarks label
every number as measured (CPU) or modeled (TPU model).

A TPU stencil kernel is VPU-bound, not MXU-bound (neighbour FMAs are vector
ops): the compute term uses ``peak_vpu_flops``.  LM workloads elsewhere in
the repo use ``peak_mxu_flops``.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Hardware", "TPU_V5E", "RTX3080_PAPER", "EngineTimes",
           "model_times", "times_from_plan"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    bw_intc: float        # host<->device interconnect, bytes/s
    bw_dmem: float        # off-chip (device/HBM) memory, bytes/s
    c_dmem: int           # off-chip capacity, bytes
    peak_vpu_flops: float  # vector unit peak (stencil FMAs), FLOP/s
    peak_mxu_flops: float  # matrix unit peak (bf16), FLOP/s
    bw_ici: float = 0.0   # per-link inter-chip interconnect, bytes/s
    n_streams: int = 3    # paper fixes N_strm = 3 (double buffering + compute)
    c_vmem: int = 0       # on-chip scratch (VMEM/shared mem), bytes; 0 = unmodeled
    t_ici_latency: float = 0.0  # per collective phase launch overhead, s
    c_dev: int = 0        # per-device working-set budget, bytes; 0 = c_dmem

    def __post_init__(self):
        # the hierarchical planner budgets a shard's resident working set
        # against c_dev; it defaults to the device-memory capacity so the
        # existing constants need no new numbers
        if self.c_dev == 0:
            object.__setattr__(self, "c_dev", self.c_dmem)


# The paper's experimental machine (Table II) — used to sanity-check the
# model against the paper's own reported numbers.
RTX3080_PAPER = Hardware(
    name="rtx3080-pcie3",
    bw_intc=12.0e9,          # PCIe gen3 x16 effective
    bw_dmem=760.0e9,
    c_dmem=10 * 1024**3,
    peak_vpu_flops=29.8e12,  # fp32 CUDA-core peak
    peak_mxu_flops=119e12,   # TC fp16 (unused for stencils)
)

# The reproduction target (assignment hardware constants).
TPU_V5E = Hardware(
    name="tpu-v5e",
    bw_intc=25.0e9,          # host DRAM <-> HBM (PCIe-class on v5e hosts)
    bw_dmem=819.0e9,         # HBM
    c_dmem=16 * 1024**3,
    peak_vpu_flops=3.9e12,   # fp32 vector peak (8 lanes*128 sublanes-ish * 2 * clock)
    peak_mxu_flops=197.0e12,  # bf16 MXU peak (assignment constant)
    bw_ici=50.0e9,           # per ICI link (assignment constant)
    c_vmem=128 * 1024**2,    # v5e VMEM per core
    t_ici_latency=1e-5,      # collective launch overhead per exchange phase
)


@dataclasses.dataclass(frozen=True)
class EngineTimes:
    """Modeled phase times, seconds (paper Fig. 7 breakdown categories)."""

    h2d: float
    d2h: float
    odc: float      # on-device copies (region-sharing buffer traffic)
    kernel: float
    kernel_mem: float      # HBM-traffic component of the kernel phase
    kernel_compute: float  # VPU component of the kernel phase

    @property
    def total_serial(self) -> float:
        return self.h2d + self.d2h + self.odc + self.kernel

    def total_overlapped(self, n_streams: int = 3) -> float:
        """With >=3 streams, copies overlap kernels (paper Sec. II/V.D):
        the pipeline settles at max(transfer, kernel+odc) plus ramp-up,
        which we approximate by the max (the paper's Sec. III model)."""
        if n_streams >= 3:
            return max(self.h2d + self.d2h, self.kernel + self.odc)
        if n_streams == 2:
            return max(self.h2d, self.d2h + self.kernel + self.odc)
        return self.total_serial


def model_times(stats, hw: Hardware) -> EngineTimes:
    """Convert engine :class:`TransferStats` into modeled phase times.

    Kernel phase: every kernel invocation streams its input band once from
    HBM and writes its output once (on-chip reuse makes neighbour taps
    free), so ``kernel_mem = hbm_bytes / bw_dmem``; compute is
    ``flops / peak_vpu``.  The two overlap on real hardware:
    ``kernel = max(mem, compute)`` per the roofline.

    Transfer phases are charged at *wire* bytes — what actually crosses
    the interconnect after a codec (arXiv 2204.11315) — which equal the
    raw bytes on uncompressed plans.  Hand-built stats that never set the
    wire fields fall back to raw bytes.
    """
    h2d_wire = getattr(stats, "h2d_wire_bytes", 0) or stats.h2d_bytes
    d2h_wire = getattr(stats, "d2h_wire_bytes", 0) or stats.d2h_bytes
    k_mem = stats.kernel_hbm_bytes / hw.bw_dmem
    k_cmp = stats.flops / hw.peak_vpu_flops
    return EngineTimes(
        h2d=h2d_wire / hw.bw_intc,
        d2h=d2h_wire / hw.bw_intc,
        odc=stats.buffer_bytes / hw.bw_dmem,
        kernel=max(k_mem, k_cmp),
        kernel_mem=k_mem,
        kernel_compute=k_cmp,
    )


def times_from_plan(plan, hw: Hardware) -> EngineTimes:
    """Model phase times straight off a compiled
    :class:`~repro.core.plan.ExecutionPlan`.

    The Sec. III terms map 1:1 onto the plan's op categories (H2D/D2H ->
    interconnect, BufferRead/Write -> off-chip copies, FusedKernel ->
    kernel roofline), so the model input *is* the planned byte count —
    there is no second accounting path to drift from."""
    return model_times(plan.stats(), hw)
