"""Plan lowering: stage programs, slot-bound closures, shape-bucketed cache.

The executors in :mod:`repro.core.executor` used to *interpret* every op
of an :class:`~repro.core.plan.ExecutionPlan` through a Python
``isinstance`` chain, with registers/buffers living in name-keyed dicts
and the fused step re-traced by JAX for every distinct band height (every
``band:r{rnd}c{i}`` register has its own shape, so a d-chunk, R-round
plan presented up to ``d*R`` signatures per kernel).  This module
compiles the plan once instead:

* **stage programs** — :func:`lower` groups ops into per-``(round,
  chunk)`` stages of *pre-bound closures*: register/buffer names are
  resolved to integer slots, slice bounds and codec objects are baked
  into each closure, and per-op type dispatch disappears from the
  execution loop (it runs ``for tag, fn in stage: fn(rt)``).
* **kernel dispatch** — FusedKernel ops are resolved through the
  registry in :mod:`repro.kernels.dispatch` (reference jnp, Pallas,
  DMA-overlapped Pallas, banded-MXU) exactly once at lowering time.
* **shape bucketing** — band heights are padded up to per-plan buckets
  (one bucket per ``(stencil, steps, keep_top, keep_bottom)`` group, the
  group's max height) so all chunks and rounds share one compiled kernel
  signature.  Padding is on the frame-free side and the output is sliced
  back to the true height, so results are bit-identical: a valid output
  row never reads a pad row (output row ``i`` depends on input rows
  ``[i - m*r, i + m*r]`` intersected with the band).  Bands framed on
  both sides (``keep_top and keep_bottom``) are never padded.
* **compilation cache** — a :class:`KernelCache` keyed by
  ``(impl, stencil, steps, keeps, bucket_height, width, itemsize)``
  counts distinct signatures; hits/misses surface in :class:`ExecStats`
  alongside wall-clock per op class.  The d=8, 4-round SO2DR config
  compiles at most one kernel per shape bucket instead of one per
  chunk x round.

Accounting is untouched: :meth:`CompiledPlan.execute` still returns the
plan-derived :class:`~repro.core.plan.TransferStats`, so dry-run numbers,
autotune sweeps, and the CI bench-gate see identical bytes whether or
not a plan is lowered.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compress import get_codec
from .plan import (
    BufferRead, BufferWrite, Compress, D2H, Decompress, ExecutionPlan,
    FusedKernel, H2D, HostCommit, TransferStats,
)

__all__ = [
    "ExecStats", "KernelCache", "CompiledPlan", "LoweredStage", "lower",
    "validate_domain",
]

# op-class tags (indices into the per-class wall-clock accumulators)
OP_TAGS = ("H2D", "D2H", "BufferWrite", "BufferRead", "FusedKernel",
           "HostCommit", "Compress", "Decompress")
_TAG = {name: i for i, name in enumerate(OP_TAGS)}

BoundOp = Tuple[int, Callable]          # (tag, closure over the runtime)


@dataclasses.dataclass
class ExecStats:
    """Execution-side counters (wall clock + compilation cache), the
    companion of the plan-side :class:`~repro.core.plan.TransferStats`.

    Wall-clock numbers are host-observed dispatch+compute time per op
    class — meaningful for comparing executors/kernels on one machine,
    never for gating CI (the cache/op counters are the deterministic
    part)."""

    executor: str = ""
    kernel_impl: str = ""
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    op_wall_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    kernel_calls: int = 0
    shape_buckets: int = 0         # distinct kernel signatures after bucketing
    kernel_compiles: int = 0       # cache misses this run (new signatures)
    kernel_cache_hits: int = 0
    stage_count: int = 0
    lower_s: float = 0.0
    wall_s: float = 0.0

    @property
    def kernel_cache_misses(self) -> int:
        return self.kernel_compiles

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["kernel_cache_misses"] = self.kernel_compiles
        return d


class KernelCache:
    """Keyed compilation cache for fused-kernel callables.

    One entry per kernel *signature* ``(impl, stencil, steps, keep_top,
    keep_bottom, bucket_height, width, itemsize)`` — the same key set
    JAX's jit cache traces on, so ``misses`` counts actual retraces and
    ``hits`` counts dispatches that reuse a compiled kernel.  Executors
    hold one cache across ``execute()`` calls, so re-running a plan (or
    running another plan with the same buckets) is all hits."""

    def __init__(self):
        self._entries: Dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0

    def lookup(self, key: tuple, make: Callable[[], Callable]) -> Callable:
        fn = self._entries.get(key)
        if fn is None:
            self.misses += 1
            fn = self._entries[key] = make()
        else:
            self.hits += 1
        return fn

    def __len__(self) -> int:
        return len(self._entries)


class _Runtime:
    """Slot-indexed register/buffer/staging state the bound closures run
    against (the lowered counterpart of the executors' old name-keyed
    device state)."""

    __slots__ = ("host", "regs", "bufs", "staged", "wire")

    def __init__(self, host: np.ndarray, n_regs: int, n_bufs: int):
        self.host = host
        self.regs: List = [None] * n_regs
        self.bufs: List = [None] * n_bufs
        # staged D2H rows: (host_lo, host_hi, device rows, codec name|None)
        self.staged: List[tuple] = []
        # reg slot -> (payload, shape, dtype) between a non-identity
        # Compress(h2d) and its Decompress
        self.wire: Dict[int, tuple] = {}

    def commit(self) -> None:
        for _, _, rows, _ in self.staged:
            jax.block_until_ready(rows)
        for host_lo, host_hi, rows, codec_name in self.staged:
            rows = np.asarray(rows)
            if codec_name is not None:
                # the wire round trip: device-side encode, host-side decode
                codec = get_codec(codec_name)
                rows = codec.decode(codec.encode(rows), rows.shape, rows.dtype)
            self.host[host_lo:host_hi] = rows
        self.staged.clear()


@dataclasses.dataclass(frozen=True)
class LoweredStage:
    """One pipeline stage: all bound ops in plan order, pre-split into
    the prefetchable prefix (H2D + host-side Compress — ops that only
    read committed host rows and write fresh slots) and the rest."""

    key: Optional[Tuple[int, int]]      # (round, chunk); None = barrier
    ops: Tuple[BoundOp, ...]
    prefetch: Tuple[BoundOp, ...]
    rest: Tuple[BoundOp, ...]


def validate_domain(plan: ExecutionPlan, x: np.ndarray) -> np.ndarray:
    """Check a host domain against the plan geometry; return a mutable copy."""
    if x.shape != (plan.Y, plan.X):
        raise ValueError(f"domain {x.shape} does not match plan "
                         f"({plan.Y}, {plan.X})")
    if x.dtype.itemsize != plan.itemsize:
        raise ValueError(f"dtype itemsize {x.dtype.itemsize} does not match "
                         f"plan itemsize {plan.itemsize}")
    return np.asarray(x).copy()


def _noop(rt) -> None:
    return None


@dataclasses.dataclass
class CompiledPlan:
    """A lowered :class:`ExecutionPlan`: stage programs of slot-bound
    closures plus the kernel-signature cache they dispatch through."""

    plan: ExecutionPlan
    stages: Tuple[LoweredStage, ...]
    n_reg_slots: int
    n_buf_slots: int
    kernel_impl: str
    shape_buckets: int
    cache: KernelCache
    lower_s: float

    def describe(self) -> dict:
        """Deterministic lowering metrics (no execution): what the CI
        bench-gate records next to the plan's byte accounting."""
        chunk_stages = sum(1 for s in self.stages if s.key is not None)
        return {
            "stage_count": chunk_stages,
            "shape_buckets": self.shape_buckets,
            "kernel_impl": self.kernel_impl,
            "reg_slots": self.n_reg_slots,
            "buf_slots": self.n_buf_slots,
        }

    def execute(self, x: np.ndarray, pipeline: bool = False,
                ) -> Tuple[np.ndarray, TransferStats, ExecStats]:
        """Run the stage programs.

        ``pipeline=True`` issues the next stage's prefetchable ops (H2D
        and host-side Compress) before the current stage's kernels — the
        double-buffered schedule; results are bitwise identical either
        way because prefetched ops only read committed host rows."""
        rt = _Runtime(validate_domain(self.plan, x),
                      self.n_reg_slots, self.n_buf_slots)
        wall = [0.0] * len(OP_TAGS)
        counts = [0] * len(OP_TAGS)
        hits0, miss0 = self.cache.hits, self.cache.misses
        perf = time.perf_counter
        t_run = perf()

        def run(ops: Tuple[BoundOp, ...]) -> None:
            for tag, fn in ops:
                t0 = perf()
                fn(rt)
                wall[tag] += perf() - t0
                counts[tag] += 1

        stages = self.stages
        if not pipeline:
            for stage in stages:
                run(stage.ops)
        else:
            n = len(stages)
            prefetched = [False] * n
            for j, stage in enumerate(stages):
                if stage.key is None:       # HostCommit barrier
                    run(stage.ops)
                    continue
                # prefetch the next chunk's transfers under this chunk's
                # kernels; never across a barrier (host rows change there)
                if j + 1 < n and stages[j + 1].key is not None:
                    run(stages[j + 1].prefetch)
                    prefetched[j + 1] = True
                run(stage.rest if prefetched[j] else stage.ops)
        rt.commit()   # no-op unless a planner forgot the final barrier

        stats = ExecStats(
            kernel_impl=self.kernel_impl,
            op_counts={OP_TAGS[i]: c for i, c in enumerate(counts) if c},
            op_wall_s={OP_TAGS[i]: wall[i] for i, c in enumerate(counts) if c},
            kernel_calls=counts[_TAG["FusedKernel"]],
            shape_buckets=self.shape_buckets,
            kernel_compiles=self.cache.misses - miss0,
            kernel_cache_hits=self.cache.hits - hits0,
            stage_count=sum(1 for s in stages if s.key is not None),
            lower_s=self.lower_s,
            wall_s=perf() - t_run,
        )
        return rt.host, self.plan.stats(), stats


class _SlotAllocator:
    """Linear-scan name->slot assignment with *delayed* slot reuse.

    Registers and buffers die at statically known ops, so slots can be
    recycled — but not immediately: the pipelined executor issues stage
    ``k``'s prefetchable ops (H2D / host-side Compress) before stage
    ``k-1``'s ops run, so a slot freed in stage ``k-1`` is still being
    read when stage ``k``'s prefetch would write it.  Holding every freed
    slot out of the pool for two chunk stages guarantees a reused slot's
    last touch strictly precedes the earliest point the pipeline can
    write it again (the prefetch of the stage after next)."""

    REUSE_DELAY = 2

    def __init__(self):
        self._live: Dict[str, int] = {}
        self._free: List[int] = []
        self._pending: List[Tuple[int, int]] = []   # (freed_at_stage, slot)
        self.n_slots = 0

    def new_stage(self, ordinal: int) -> None:
        """Called when lowering enters chunk stage ``ordinal``: slots
        freed at least ``REUSE_DELAY`` stages ago become reusable."""
        keep = []
        for freed_at, slot in self._pending:
            if freed_at <= ordinal - self.REUSE_DELAY:
                self._free.append(slot)
            else:
                keep.append((freed_at, slot))
        self._pending = keep

    def alloc(self, name: str) -> int:
        assert name not in self._live, f"slot name {name!r} already live"
        if self._free:
            slot = self._free.pop()
        else:
            slot = self.n_slots
            self.n_slots += 1
        self._live[name] = slot
        return slot

    def get(self, name: str) -> int:
        return self._live[name]

    def free(self, name: str, stage_ordinal: int) -> int:
        slot = self._live.pop(name)
        self._pending.append((stage_ordinal, slot))
        return slot


def _bucket_heights(plan: ExecutionPlan, bucket: bool) -> Dict[tuple, int]:
    """Per-group padded band heights: one bucket per ``(stencil, steps,
    keep_top, keep_bottom)`` group (its max h_in).  Both-sides-framed
    bands are excluded — there is no frame-free side to pad."""
    buckets: Dict[tuple, int] = {}
    if not bucket:
        return buckets
    for op in plan.ops:
        if isinstance(op, FusedKernel) and not (op.keep_top and op.keep_bottom):
            key = (op.stencil, op.steps, op.keep_top, op.keep_bottom)
            buckets[key] = max(buckets.get(key, 0), op.h_in)
    return buckets


def _bind_kernel(slot: int, op: FusedKernel, bucket_h: int, impl_name: str,
                 fn: Callable, cache: KernelCache, itemsize: int) -> Callable:
    pad = bucket_h - op.h_in
    # pad on the frame-free side; slice the true output back out
    pad_top = op.keep_bottom and not op.keep_top
    # id(fn) keeps the signature count honest when the same impl name
    # resolves to a different callable (swapped fused_step, new tile):
    # the cache entry holds fn alive, so its id cannot be reused while
    # the key is live.  The callable itself is always the freshly
    # resolved fn — the cache only counts, it never serves stale code.
    key = (impl_name, id(fn), op.stencil, op.steps, op.keep_top,
           op.keep_bottom, bucket_h, op.width, itemsize)
    name, steps = op.stencil, op.steps
    kt, kb = op.keep_top, op.keep_bottom
    h_out = op.h_out

    def run(rt):
        cache.lookup(key, lambda: fn)
        band = rt.regs[slot]
        if pad:
            z = jnp.zeros((pad, band.shape[1]), band.dtype)
            band = jnp.concatenate([z, band] if pad_top else [band, z], axis=0)
        out = fn(band, name, steps, keep_top=kt, keep_bottom=kb)
        if pad:
            out = out[out.shape[0] - h_out:] if pad_top else out[:h_out]
        rt.regs[slot] = out

    return run


def lower(plan: ExecutionPlan, policy=None, fused_step=None,
          kernel_cache: Optional[KernelCache] = None) -> CompiledPlan:
    """Compile a plan into stage programs of slot-bound closures.

    ``fused_step`` (an explicit ``fn(band, name, steps, keep_top=...,
    keep_bottom=...)`` callable) overrides the dispatch registry;
    otherwise ``policy`` (a :class:`repro.kernels.dispatch.DispatchPolicy`,
    default ``auto``) picks the implementation per stencil/steps/backend.
    ``kernel_cache`` lets an executor share one signature cache across
    plans and runs."""
    from repro.kernels.dispatch import DispatchPolicy, select_kernel

    t0 = time.perf_counter()
    policy = policy or DispatchPolicy()
    cache = kernel_cache if kernel_cache is not None else KernelCache()
    buckets = _bucket_heights(plan, policy.bucket)

    regs = _SlotAllocator()
    bufs = _SlotAllocator()
    # (stencil, steps) -> (impl_name, callable); resolved once at lower time
    kernels: Dict[tuple, Tuple[str, Callable]] = {}
    # statically tracked codec context between a Compress and its transfer
    pending_h2d: Dict[str, str] = {}    # reg -> codec (non-identity, h2d)
    pending_d2h: Dict[str, str] = {}    # reg -> codec (non-identity, d2h)

    signatures = set()
    stages: List[List] = []             # [key, [BoundOp...]]
    chunk_ordinal = -1                  # index of the current chunk stage

    def emit(key, tag: str, fn: Callable) -> None:
        if stages and stages[-1][0] == key and key is not None:
            stages[-1][1].append((_TAG[tag], fn))
        else:
            stages.append([key, [(_TAG[tag], fn)]])

    for op in plan.ops:
        if isinstance(op, HostCommit):
            emit(None, "HostCommit", _Runtime.commit)
            continue
        key = (op.round, op.chunk)
        if not stages or stages[-1][0] != key:
            chunk_ordinal += 1
            regs.new_stage(chunk_ordinal)
            bufs.new_stage(chunk_ordinal)
        if isinstance(op, Compress):
            if op.direction == "h2d":
                codec = get_codec(op.codec)
                if codec.name == "identity":
                    # identity fast path: skip the encode/decode byte
                    # round trip — the H2D itself is the (pure) copy;
                    # wire-byte accounting stays plan-derived
                    emit(key, "Compress", _noop)
                else:
                    slot = regs.alloc(op.reg)   # H2D binds as the wire hop
                    pending_h2d[op.reg] = op.codec
                    lo, hi = op.host_lo, op.host_hi

                    def run(rt, _s=slot, _lo=lo, _hi=hi, _c=codec):
                        rows = rt.host[_lo:_hi]
                        rt.wire[_s] = (jnp.asarray(_c.encode(rows)),
                                       rows.shape, rows.dtype)

                    emit(key, "Compress", run)
            else:
                if op.codec != "identity":
                    pending_d2h[op.reg] = op.codec
                emit(key, "Compress", _noop)
        elif isinstance(op, Decompress):
            if op.direction == "h2d" and op.codec != "identity":
                slot = regs.get(op.reg)
                codec = get_codec(op.codec)

                def run(rt, _s=slot, _c=codec):
                    payload, shape, dtype = rt.wire.pop(_s)
                    rt.regs[_s] = jnp.asarray(
                        _c.decode(np.asarray(payload), shape, dtype))

                emit(key, "Decompress", run)
            else:
                # d2h decode runs at the HostCommit barrier (the first
                # point the device bytes are forced anyway)
                emit(key, "Decompress", _noop)
        elif isinstance(op, H2D):
            if op.reg in pending_h2d:
                # the wire hop already carried the encoded payload
                del pending_h2d[op.reg]
                emit(key, "H2D", _noop)
            else:
                slot = regs.alloc(op.reg)
                lo, hi = op.host_lo, op.host_hi

                def run(rt, _s=slot, _lo=lo, _hi=hi):
                    rt.regs[_s] = jnp.asarray(rt.host[_lo:_hi])

                emit(key, "H2D", run)
        elif isinstance(op, BufferWrite):
            rslot = regs.get(op.reg)
            bslot = bufs.alloc(op.buf)
            lo, hi = op.reg_lo, op.reg_hi

            def run(rt, _b=bslot, _r=rslot, _lo=lo, _hi=hi):
                rt.bufs[_b] = rt.regs[_r][_lo:_hi]

            emit(key, "BufferWrite", run)
        elif isinstance(op, BufferRead):
            bslot = bufs.free(op.buf, chunk_ordinal)    # consumed exactly once
            src_slot = regs.free(op.src, chunk_ordinal)  # src dies here
            dst_slot = regs.alloc(op.reg)

            def run(rt, _b=bslot, _src=src_slot, _dst=dst_slot):
                shared = rt.bufs[_b]
                rt.bufs[_b] = None
                src = rt.regs[_src]
                if _src != _dst:
                    rt.regs[_src] = None
                rt.regs[_dst] = jnp.concatenate([shared, src], axis=0)

            emit(key, "BufferRead", run)
        elif isinstance(op, FusedKernel):
            slot = regs.get(op.reg)
            kkey = (op.stencil, op.steps)
            if kkey not in kernels:
                if fused_step is not None:
                    kernels[kkey] = ("explicit", fused_step)
                else:
                    kernels[kkey] = select_kernel(op.stencil, op.steps, policy)
            impl_name, fn = kernels[kkey]
            gkey = (op.stencil, op.steps, op.keep_top, op.keep_bottom)
            bucket_h = buckets.get(gkey, op.h_in)
            signatures.add(gkey + (bucket_h,))
            emit(key, "FusedKernel",
                 _bind_kernel(slot, op, bucket_h, impl_name, fn, cache,
                              plan.itemsize))
        elif isinstance(op, D2H):
            slot = regs.free(op.reg, chunk_ordinal)   # last use of the register
            codec_name = pending_d2h.pop(op.reg, None)
            rlo, rhi, hlo, hhi = op.reg_lo, op.reg_hi, op.host_lo, op.host_hi

            def run(rt, _s=slot, _rlo=rlo, _rhi=rhi, _hlo=hlo, _hhi=hhi,
                    _codec=codec_name):
                band = rt.regs[_s]
                rt.regs[_s] = None
                rt.staged.append((_hlo, _hhi, band[_rlo:_rhi], _codec))

            emit(key, "D2H", run)
        else:  # pragma: no cover - planner/lowering version skew
            raise TypeError(f"unknown op {op!r}")

    impl_names = sorted({name for name, _ in kernels.values()})
    lowered_stages = []
    for key, ops in stages:
        ops = tuple(ops)
        prefetch = tuple(
            (tag, fn) for tag, fn in ops
            if tag == _TAG["H2D"] or tag == _TAG["Compress"])
        rest = tuple(b for b in ops if b not in prefetch)
        lowered_stages.append(LoweredStage(key=key, ops=ops,
                                           prefetch=prefetch, rest=rest))
    return CompiledPlan(
        plan=plan,
        stages=tuple(lowered_stages),
        n_reg_slots=regs.n_slots,
        n_buf_slots=bufs.n_slots,
        kernel_impl="+".join(impl_names) if impl_names else "none",
        shape_buckets=len(signatures),
        cache=cache,
        lower_s=time.perf_counter() - t0,
    )
