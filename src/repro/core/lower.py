"""Plan lowering: stage programs, slot-bound closures, shape-bucketed cache.

The executors in :mod:`repro.core.executor` used to *interpret* every op
of an :class:`~repro.core.plan.ExecutionPlan` through a Python
``isinstance`` chain, with registers/buffers living in name-keyed dicts
and the fused step re-traced by JAX for every distinct band height (every
``band:r{rnd}c{i}`` register has its own shape, so a d-chunk, R-round
plan presented up to ``d*R`` signatures per kernel).  This module
compiles the plan once instead:

* **stage programs** — :func:`lower` groups ops into per-``(round,
  chunk)`` stages of *pre-bound closures*: register/buffer names are
  resolved to integer slots, slice bounds and codec objects are baked
  into each closure, and per-op type dispatch disappears from the
  execution loop (it runs ``for tag, fn, rnd, chunk in stage: fn(rt)``;
  the trailing site pair addresses fault injection).
* **kernel dispatch** — FusedKernel ops are resolved through the
  registry in :mod:`repro.kernels.dispatch` (reference jnp, Pallas,
  DMA-overlapped Pallas, banded-MXU) exactly once at lowering time.
* **shape bucketing** — band heights are padded up to per-plan buckets
  (one bucket per ``(stencil, steps, keep_top, keep_bottom)`` group, the
  group's max height) so all chunks and rounds share one compiled kernel
  signature.  Padding is on the frame-free side and the output is sliced
  back to the true height, so results are bit-identical: a valid output
  row never reads a pad row (output row ``i`` depends on input rows
  ``[i - m*r, i + m*r]`` intersected with the band).  Bands framed on
  both sides (``keep_top and keep_bottom``) are never padded.
* **compilation cache** — a :class:`KernelCache` keyed by
  ``(impl, stencil, steps, keeps, bucket_height, width, itemsize)``
  counts distinct signatures; hits/misses surface in :class:`ExecStats`
  alongside wall-clock per op class.  The d=8, 4-round SO2DR config
  compiles at most one kernel per shape bucket instead of one per
  chunk x round.

Accounting is untouched: :meth:`CompiledPlan.execute` still returns the
plan-derived :class:`~repro.core.plan.TransferStats`, so dry-run numbers,
autotune sweeps, and the CI bench-gate see identical bytes whether or
not a plan is lowered.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compress import get_codec
from .faults import InjectedFault, consult
from .plan import (
    Box, BufferRead, BufferWrite, Compress, D2H, Decompress, ExecutionPlan,
    FusedKernel, H2D, HaloCompress, HaloDecompress, HaloRecv, HaloSend,
    HostCommit, ShardKernel, ShardLoad, ShardStore, ShardedPlan,
    TransferStats,
)

__all__ = [
    "ExecStats", "KernelCache", "BucketRegistry", "SlotPool",
    "CompiledPlan", "LoweredStage", "lower",
    "CompiledShardedPlan", "ShardStage", "lower_sharded",
    "check_domain", "validate_domain",
]

# op-class tags (indices into the per-class wall-clock accumulators)
OP_TAGS = ("H2D", "D2H", "BufferWrite", "BufferRead", "FusedKernel",
           "HostCommit", "Compress", "Decompress",
           "ShardLoad", "ShardStore", "HaloSend", "HaloRecv", "ShardKernel",
           "HaloCompress", "HaloDecompress")
_TAG = {name: i for i, name in enumerate(OP_TAGS)}

# (tag, closure over the runtime, round, chunk) — the trailing site pair
# is the fault-injection address: repro.core.faults consults it before
# the closure runs, so an injected fault never leaves a half-executed op
BoundOp = Tuple[int, Callable, int, int]


@dataclasses.dataclass
class ExecStats:
    """Execution-side counters (wall clock + compilation cache), the
    companion of the plan-side :class:`~repro.core.plan.TransferStats`.

    Wall-clock numbers are host-observed dispatch+compute time per op
    class — meaningful for comparing executors/kernels on one machine,
    never for gating CI (the cache/op counters are the deterministic
    part)."""

    executor: str = ""
    kernel_impl: str = ""
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    op_wall_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    kernel_calls: int = 0
    shape_buckets: int = 0         # distinct kernel signatures after bucketing
    kernel_compiles: int = 0       # cache misses this run (new signatures)
    kernel_cache_hits: int = 0
    stage_count: int = 0
    lower_s: float = 0.0
    wall_s: float = 0.0
    faults_injected: int = 0       # injected faults hit this run
    retries: int = 0               # transient faults absorbed by backoff
    resumes: int = 0               # checkpoint resumes (recovery loop)
    modeled_s: Optional[float] = None     # Sec. III prediction for this run
    model_error: Optional[float] = None   # (modeled_s - wall_s) / wall_s

    def __post_init__(self):
        # plain attribute, not a dataclass field: asdict/== never see it
        self._lock = threading.Lock()

    @property
    def kernel_cache_misses(self) -> int:
        return self.kernel_compiles

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["kernel_cache_misses"] = self.kernel_compiles
        return d

    def merge(self, other: "ExecStats") -> "ExecStats":
        """Accumulate another run's counters and stage timers into this
        one, thread-safely — the aggregation a long-lived service does as
        concurrent jobs complete.  Counters and wall clocks sum;
        ``shape_buckets``/``stage_count`` sum per-run values (a shared
        signature counts once per run that used it); identity fields keep
        the first non-empty value."""
        with self._lock:
            for k, v in other.op_counts.items():
                self.op_counts[k] = self.op_counts.get(k, 0) + v
            for k, v in other.op_wall_s.items():
                self.op_wall_s[k] = self.op_wall_s.get(k, 0.0) + v
            self.kernel_calls += other.kernel_calls
            self.shape_buckets += other.shape_buckets
            self.kernel_compiles += other.kernel_compiles
            self.kernel_cache_hits += other.kernel_cache_hits
            self.stage_count += other.stage_count
            self.lower_s += other.lower_s
            self.wall_s += other.wall_s
            self.faults_injected += other.faults_injected
            self.retries += other.retries
            self.resumes += other.resumes
            self.executor = self.executor or other.executor
            self.kernel_impl = self.kernel_impl or other.kernel_impl
            if other.modeled_s is not None:
                self.modeled_s = (self.modeled_s or 0.0) + other.modeled_s
            if self.modeled_s is not None and self.wall_s > 0:
                self.model_error = ((self.modeled_s - self.wall_s)
                                    / self.wall_s)
        return self


class KernelCache:
    """Keyed compilation cache for fused-kernel callables.

    One entry per kernel *signature* ``(impl, stencil, steps, keep_top,
    keep_bottom, bucket_height, width, itemsize)`` — the same key set
    JAX's jit cache traces on, so ``misses`` counts actual retraces and
    ``hits`` counts dispatches that reuse a compiled kernel.  Executors
    hold one cache across ``execute()`` calls, so re-running a plan (or
    running another plan with the same buckets) is all hits.

    Thread-safe: a service shares one warm cache across concurrent jobs,
    and CI gates on the hit/miss counters, so lookups (including the
    ``make`` call on a miss) run under a lock — a signature is compiled
    and counted exactly once no matter how many jobs race to it."""

    def __init__(self):
        self._entries: Dict[tuple, Callable] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def lookup(self, key: tuple, make: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._entries.get(key)
            if fn is None:
                self.misses += 1
                fn = self._entries[key] = make()
            else:
                self.hits += 1
            return fn

    def snapshot(self) -> Tuple[int, int]:
        """Atomic ``(hits, misses)`` read — per-job compile attribution
        in a shared-cache service needs both counters from one instant."""
        with self._lock:
            return self.hits, self.misses

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class BucketRegistry:
    """Cross-plan shape buckets: the service-lifetime companion of the
    per-plan bucketing pass.

    Maps a kernel group ``(stencil, steps, keep_top, keep_bottom, width,
    itemsize)`` to the band heights already compiled for it.  When
    :func:`lower` routes a plan through a registry, each group's padded
    height becomes the smallest registered bucket that fits (registering
    a new one only when none does), so a job with an *unseen shape* whose
    bands fit existing buckets presents zero new kernel signatures to a
    warm :class:`KernelCache` — the shape-bucketing pass amortized across
    jobs instead of within one.  Padding stays on the frame-free side,
    so results remain bit-identical (both-sides-framed groups never
    reach the registry).  Thread-safe."""

    def __init__(self):
        self._heights: Dict[tuple, List[int]] = {}
        self._lock = threading.Lock()

    def resolve(self, group: tuple, height: int) -> int:
        """Smallest registered bucket >= ``height`` for ``group``; when
        none fits, ``height`` is registered as a new bucket."""
        with self._lock:
            heights = self._heights.setdefault(group, [])
            i = bisect.bisect_left(heights, height)
            if i < len(heights):
                return heights[i]
            heights.insert(i, height)
            return height

    def __len__(self) -> int:
        """Total registered buckets (over all groups)."""
        with self._lock:
            return sum(len(v) for v in self._heights.values())


class SlotPool:
    """Device buffer-slot storage shared and reused across compiled plans.

    A long-lived service owns one pool for its lifetime: every job leases
    register/buffer slot storage when its runtime is built and releases
    it when the job retires, so steady-state serving re-allocates no slot
    storage per job (``reuses``/``peak_in_use`` make that observable).
    Leases are exclusive — concurrent jobs each hold their own storage —
    and release clears every slot so no device buffer outlives its job.
    Thread-safe."""

    def __init__(self):
        self._free: List[Tuple[List, List]] = []
        self._lock = threading.Lock()
        self.leases = 0
        self.reuses = 0
        self.in_use = 0
        self.peak_in_use = 0

    def acquire(self, n_regs: int, n_bufs: int) -> Tuple[List, List]:
        with self._lock:
            self.leases += 1
            if self._free:
                self.reuses += 1
                regs, bufs = self._free.pop()
            else:
                regs, bufs = [], []
            self.in_use += 1
            self.peak_in_use = max(self.peak_in_use, self.in_use)
        if len(regs) < n_regs:
            regs.extend([None] * (n_regs - len(regs)))
        if len(bufs) < n_bufs:
            bufs.extend([None] * (n_bufs - len(bufs)))
        return regs, bufs

    def release(self, regs: List, bufs: List) -> None:
        for i in range(len(regs)):
            regs[i] = None
        for i in range(len(bufs)):
            bufs[i] = None
        with self._lock:
            self._free.append((regs, bufs))
            self.in_use -= 1

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"leases": self.leases, "reuses": self.reuses,
                    "in_use": self.in_use, "peak_in_use": self.peak_in_use}

    def assert_balanced(self) -> None:
        """Raise if any lease is still outstanding.

        The audit hook for quiescent points (end of a job, service
        drain): every ``acquire`` must have been paired with a
        ``release`` — including on exception paths, where the lowered
        executors release in ``finally`` — so a non-zero ``in_use`` here
        is a leaked lease, i.e. device slot storage pinned by a job that
        already retired."""
        with self._lock:
            if self.in_use != 0:
                raise AssertionError(
                    f"slot pool unbalanced: {self.in_use} lease(s) "
                    f"outstanding ({self.leases} acquired, "
                    f"{self.leases - self.in_use} released)")


class _Runtime:
    """Slot-indexed register/buffer/staging state the bound closures run
    against (the lowered counterpart of the executors' old name-keyed
    device state)."""

    __slots__ = ("host", "regs", "bufs", "staged", "wire",
                 "on_commit", "committed_round")

    def __init__(self, host: np.ndarray, n_regs: int, n_bufs: int,
                 regs: Optional[List] = None, bufs: Optional[List] = None):
        self.host = host
        # recovery hooks: the newest round whose barrier fully drained
        # (-1 = none), and an optional per-round checkpoint callback
        self.on_commit: Optional[Callable[[int, np.ndarray], None]] = None
        self.committed_round = -1
        # storage may be leased from a SlotPool (possibly longer than
        # needed — closures only ever index their bound slots)
        self.regs: List = regs if regs is not None else [None] * n_regs
        self.bufs: List = bufs if bufs is not None else [None] * n_bufs
        # staged D2H boxes: (host slice tuple, device payload, codec|None)
        self.staged: List[tuple] = []
        # reg slot -> (payload, shape, dtype) between a non-identity
        # Compress(h2d) and its Decompress
        self.wire: Dict[int, tuple] = {}

    def commit(self) -> None:
        for _, rows, _ in self.staged:
            jax.block_until_ready(rows)
        for sl, rows, codec_name in self.staged:
            rows = np.asarray(rows)
            if codec_name is not None:
                # the wire round trip: device-side encode, host-side decode
                codec = get_codec(codec_name)
                rows = codec.decode(codec.encode(rows), rows.shape, rows.dtype)
            self.host[sl] = rows
        self.staged.clear()

    def commit_round(self, rnd: int) -> None:
        """A round's HostCommit barrier: drain staged writes, record the
        round as the recovery point, fire the checkpoint hook (the host
        array is the complete machine state here — nothing else survives
        a barrier)."""
        self.commit()
        self.committed_round = rnd
        if self.on_commit is not None:
            self.on_commit(rnd, self.host)


@dataclasses.dataclass(frozen=True)
class LoweredStage:
    """One pipeline stage: all bound ops in plan order, pre-split into
    the prefetchable prefix (H2D + host-side Compress — ops that only
    read committed host rows and write fresh slots) and the rest."""

    key: Optional[Tuple[int, int]]      # (round, chunk); None = barrier
    ops: Tuple[BoundOp, ...]
    prefetch: Tuple[BoundOp, ...]
    rest: Tuple[BoundOp, ...]


def check_domain(plan, x: np.ndarray) -> None:
    """Raise if a host domain does not match the plan geometry.

    Shared by every executor entry point (including the shard_map
    backend, which needs no mutable copy), so all backends reject
    identically by construction."""
    if tuple(x.shape) != tuple(plan.shape):
        raise ValueError(f"domain {x.shape} does not match plan "
                         f"{tuple(plan.shape)}")
    if x.dtype.itemsize != plan.itemsize:
        raise ValueError(f"dtype itemsize {x.dtype.itemsize} does not match "
                         f"plan itemsize {plan.itemsize}")


def validate_domain(plan: ExecutionPlan, x: np.ndarray) -> np.ndarray:
    """Check a host domain against the plan geometry; return a mutable copy."""
    check_domain(plan, x)
    return np.asarray(x).copy()


def _noop(rt) -> None:
    return None


@dataclasses.dataclass
class CompiledPlan:
    """A lowered :class:`ExecutionPlan`: stage programs of slot-bound
    closures plus the kernel-signature cache they dispatch through."""

    plan: ExecutionPlan
    stages: Tuple[LoweredStage, ...]
    n_reg_slots: int
    n_buf_slots: int
    kernel_impl: str
    shape_buckets: int
    cache: KernelCache
    lower_s: float

    def describe(self) -> dict:
        """Deterministic lowering metrics (no execution): what the CI
        bench-gate records next to the plan's byte accounting."""
        chunk_stages = sum(1 for s in self.stages if s.key is not None)
        return {
            "stage_count": chunk_stages,
            "shape_buckets": self.shape_buckets,
            "kernel_impl": self.kernel_impl,
            "reg_slots": self.n_reg_slots,
            "buf_slots": self.n_buf_slots,
        }

    def runtime(self, x: np.ndarray,
                slot_pool: Optional[SlotPool] = None) -> _Runtime:
        """Build the slot-indexed runtime for one run, leasing slot
        storage from ``slot_pool`` when given (release it back with
        :meth:`release_runtime` when the run retires)."""
        host = validate_domain(self.plan, x)
        if slot_pool is None:
            return _Runtime(host, self.n_reg_slots, self.n_buf_slots)
        regs, bufs = slot_pool.acquire(self.n_reg_slots, self.n_buf_slots)
        return _Runtime(host, self.n_reg_slots, self.n_buf_slots, regs, bufs)

    @staticmethod
    def release_runtime(rt: _Runtime,
                        slot_pool: Optional[SlotPool]) -> None:
        if slot_pool is not None:
            slot_pool.release(rt.regs, rt.bufs)

    def execute(self, x: np.ndarray, pipeline: bool = False,
                slot_pool: Optional[SlotPool] = None,
                injector=None, retry=None, on_commit=None,
                ) -> Tuple[np.ndarray, TransferStats, ExecStats]:
        """Run the stage programs.

        ``pipeline=True`` issues the next stage's prefetchable ops (H2D
        and host-side Compress) before the current stage's kernels — the
        double-buffered schedule; results are bitwise identical either
        way because prefetched ops only read committed host rows.
        ``slot_pool`` leases the runtime's slot storage from a shared
        pool instead of allocating fresh lists.

        ``injector`` (a :class:`repro.core.faults.FaultInjector`) is
        consulted before every bound op; transient faults are retried in
        place under ``retry`` (a :class:`repro.core.faults.RetryPolicy`),
        terminal faults surface as a typed
        :class:`repro.core.recovery.PlanExecutionError` carrying the
        last committed round.  ``on_commit(round, host)`` fires after
        every round's barrier drains — the checkpoint hook.  Leased slot
        storage is released on *every* exit path (faulted runs do not
        leak pool occupancy)."""
        rt = self.runtime(x, slot_pool)
        rt.on_commit = on_commit
        wall = [0.0] * len(OP_TAGS)
        counts = [0] * len(OP_TAGS)
        hits0, miss0 = self.cache.hits, self.cache.misses
        f0 = injector.faults_injected if injector is not None else 0
        r0 = injector.retries if injector is not None else 0
        perf = time.perf_counter
        t_run = perf()

        def run(ops: Tuple[BoundOp, ...]) -> None:
            for tag, fn, rnd, chunk in ops:
                if injector is not None:
                    consult(injector, retry, rnd, chunk, OP_TAGS[tag])
                t0 = perf()
                fn(rt)
                wall[tag] += perf() - t0
                counts[tag] += 1

        stages = self.stages
        try:
            if not pipeline:
                for stage in stages:
                    run(stage.ops)
            else:
                n = len(stages)
                prefetched = [False] * n
                for j, stage in enumerate(stages):
                    if stage.key is None:       # HostCommit barrier
                        run(stage.ops)
                        continue
                    # prefetch the next chunk's transfers under this
                    # chunk's kernels; never across a barrier (host rows
                    # change there)
                    if j + 1 < n and stages[j + 1].key is not None:
                        run(stages[j + 1].prefetch)
                        prefetched[j + 1] = True
                    run(stage.rest if prefetched[j] else stage.ops)
            rt.commit()   # no-op unless a planner forgot the final barrier
        except InjectedFault as f:
            from .recovery import PlanExecutionError, plan_fingerprint
            raise PlanExecutionError(
                f"plan execution failed at round={f.round} "
                f"chunk={f.chunk} op={f.op_class}: {f.kind} "
                f"(last committed round {rt.committed_round})",
                fault=f, last_committed_round=rt.committed_round,
                fingerprint=plan_fingerprint(self.plan)) from f
        finally:
            self.release_runtime(rt, slot_pool)

        stats = ExecStats(
            kernel_impl=self.kernel_impl,
            op_counts={OP_TAGS[i]: c for i, c in enumerate(counts) if c},
            op_wall_s={OP_TAGS[i]: wall[i] for i, c in enumerate(counts) if c},
            kernel_calls=counts[_TAG["FusedKernel"]],
            shape_buckets=self.shape_buckets,
            kernel_compiles=self.cache.misses - miss0,
            kernel_cache_hits=self.cache.hits - hits0,
            stage_count=sum(1 for s in stages if s.key is not None),
            lower_s=self.lower_s,
            wall_s=perf() - t_run,
            faults_injected=(injector.faults_injected - f0)
            if injector is not None else 0,
            retries=(injector.retries - r0) if injector is not None else 0,
        )
        return rt.host, self.plan.stats(), stats


class _SlotAllocator:
    """Linear-scan name->slot assignment with *delayed* slot reuse.

    Registers and buffers die at statically known ops, so slots can be
    recycled — but not immediately: the pipelined executor issues stage
    ``k``'s prefetchable ops (H2D / host-side Compress) before stage
    ``k-1``'s ops run, so a slot freed in stage ``k-1`` is still being
    read when stage ``k``'s prefetch would write it.  Holding every freed
    slot out of the pool for two chunk stages guarantees a reused slot's
    last touch strictly precedes the earliest point the pipeline can
    write it again (the prefetch of the stage after next)."""

    REUSE_DELAY = 2

    def __init__(self):
        self._live: Dict[str, int] = {}
        self._free: List[int] = []
        self._pending: List[Tuple[int, int]] = []   # (freed_at_stage, slot)
        self.n_slots = 0

    def new_stage(self, ordinal: int) -> None:
        """Called when lowering enters chunk stage ``ordinal``: slots
        freed at least ``REUSE_DELAY`` stages ago become reusable."""
        keep = []
        for freed_at, slot in self._pending:
            if freed_at <= ordinal - self.REUSE_DELAY:
                self._free.append(slot)
            else:
                keep.append((freed_at, slot))
        self._pending = keep

    def alloc(self, name: str) -> int:
        assert name not in self._live, f"slot name {name!r} already live"
        if self._free:
            slot = self._free.pop()
        else:
            slot = self.n_slots
            self.n_slots += 1
        self._live[name] = slot
        return slot

    def get(self, name: str) -> int:
        return self._live[name]

    def free(self, name: str, stage_ordinal: int) -> int:
        slot = self._live.pop(name)
        self._pending.append((stage_ordinal, slot))
        return slot


def _is_banded(op: FusedKernel) -> bool:
    """True for a classic 2-D row band (full width, frame columns along)
    — the shape the registered fused-step kernels and the bucketing pass
    understand.  Anything else (3-D tiles, column chunks) lowers through
    the N-D reference binder."""
    return len(op.shape_in) == 2 and op.keep_lo[1] and op.keep_hi[1]


def _bucket_heights(plan: ExecutionPlan, bucket: bool,
                    registry: Optional[BucketRegistry] = None,
                    ) -> Dict[tuple, int]:
    """Per-group padded band heights: one bucket per ``(stencil, steps,
    keep_top, keep_bottom)`` group (its max h_in).  Both-sides-framed
    bands are excluded — there is no frame-free side to pad — and so are
    non-banded (N-D box) kernels, which have no single pad axis.  A
    :class:`BucketRegistry` lifts each group's height to the smallest
    already-compiled cross-plan bucket that fits, so warm-service jobs
    with unseen shapes reuse existing kernel signatures."""
    buckets: Dict[tuple, int] = {}
    if not bucket:
        return buckets
    for op in plan.ops:
        if isinstance(op, FusedKernel) and _is_banded(op) \
                and not (op.keep_lo[0] and op.keep_hi[0]):
            key = (op.stencil, op.steps, op.keep_lo[0], op.keep_hi[0])
            buckets[key] = max(buckets.get(key, 0), op.shape_in[0])
    if registry is not None:
        for key, h in buckets.items():
            buckets[key] = registry.resolve(
                key + (plan.X, plan.itemsize), h)
    return buckets


def _bind_kernel(slot: int, op: FusedKernel, bucket_h: int, impl_name: str,
                 fn: Callable, cache: KernelCache, itemsize: int) -> Callable:
    h_in, width = op.shape_in
    pad = bucket_h - h_in
    kt, kb = op.keep_lo[0], op.keep_hi[0]
    # pad on the frame-free side; slice the true output back out
    pad_top = kb and not kt
    # id(fn) keeps the signature count honest when the same impl name
    # resolves to a different callable (swapped fused_step, new tile):
    # the cache entry holds fn alive, so its id cannot be reused while
    # the key is live.  The callable itself is always the freshly
    # resolved fn — the cache only counts, it never serves stale code.
    key = (impl_name, id(fn), op.stencil, op.steps, kt, kb,
           bucket_h, width, itemsize)
    name, steps = op.stencil, op.steps
    h_out = op.shape_out[0]

    def run(rt):
        cache.lookup(key, lambda: fn)
        band = rt.regs[slot]
        if pad:
            z = jnp.zeros((pad, band.shape[1]), band.dtype)
            band = jnp.concatenate([z, band] if pad_top else [band, z], axis=0)
        out = fn(band, name, steps, keep_top=kt, keep_bottom=kb)
        if pad:
            out = out[out.shape[0] - h_out:] if pad_top else out[:h_out]
        rt.regs[slot] = out

    return run


def _bind_kernel_nd(slot: int, op: FusedKernel, cache: KernelCache,
                    itemsize: int) -> Callable:
    """Bind a non-banded (N-D box) FusedKernel to the reference kernel.

    No padding/bucketing: each distinct ``(shape_in, keeps)`` is its own
    jit signature, and the cache key mirrors that so ``shape_buckets``
    keeps counting the true compile ceiling."""
    from .reference import multi_step_box

    key = ("reference_nd", op.stencil, op.steps, op.keep_lo, op.keep_hi,
           op.shape_in, itemsize)
    name, steps, kl, kh = op.stencil, op.steps, op.keep_lo, op.keep_hi

    def run(rt):
        cache.lookup(key, lambda: multi_step_box)
        rt.regs[slot] = multi_step_box(rt.regs[slot], name, steps,
                                       keep_lo=kl, keep_hi=kh)

    return run


def _bind_kernel_masked(slot: int, op: FusedKernel, box: Box,
                        origin: Tuple[int, int, int, int],
                        cache: KernelCache, itemsize: int) -> Callable:
    """Bind a hierarchical inner FusedKernel to the globally-masked
    update (:func:`repro.core.distributed.masked_local_steps`).

    ``box`` is the register's ext in band coordinates; ``origin`` maps
    the band into the global framed domain ``(gy0, gx0, Yg, Xg)``.  The
    per-chunk global offsets are *traced* arguments, so every chunk of
    every rank with the same ext shape shares one compiled signature —
    the same trick :func:`_bind_shard_kernel` plays one level up.  No
    crop here: the masked step preserves the ext's frame, and the D2H
    that follows selects only the rows/cols at halo depth."""
    from .distributed import masked_local_steps
    from .stencil import get_stencil

    st = get_stencil(op.stencil)
    gy0, gx0, Yg, Xg = origin
    key = ("hier", op.stencil, op.steps, op.shape_in, Yg, Xg, itemsize)
    oy, ox = gy0 + box.lo[0], gx0 + box.lo[1]
    steps = op.steps

    def make() -> Callable:
        def f(ext, y0, x0):
            return masked_local_steps(ext, st, steps, y0, x0, Yg, Xg)
        return jax.jit(f)

    def run(rt):
        fn = cache.lookup(key, make)
        rt.regs[slot] = fn(rt.regs[slot], oy, ox)

    return run


def lower(plan: ExecutionPlan, policy=None, fused_step=None,
          kernel_cache: Optional[KernelCache] = None,
          bucket_registry: Optional[BucketRegistry] = None,
          shard_origin: Optional[Tuple[int, int, int, int]] = None,
          ) -> CompiledPlan:
    """Compile a plan into stage programs of slot-bound closures.

    ``fused_step`` (an explicit ``fn(band, name, steps, keep_top=...,
    keep_bottom=...)`` callable) overrides the dispatch registry;
    otherwise ``policy`` (a :class:`repro.kernels.dispatch.DispatchPolicy`,
    default ``auto``) picks the implementation per stencil/steps/backend.
    ``kernel_cache`` lets an executor share one signature cache across
    plans and runs; ``bucket_registry`` additionally routes this plan's
    band heights to already-registered cross-plan buckets so a warm
    service compiles zero new kernels for shapes that fit an existing
    bucket.

    ``shard_origin`` switches the kernel binding to hierarchical inner
    semantics: the plan's domain is one shard's halo-extended band at
    global origin ``(gy0, gx0)`` inside a ``(Yg, Xg)`` framed domain,
    and every FusedKernel runs the globally-masked update instead of
    the frame-shrinking fused step (:func:`_bind_kernel_masked`)."""
    from repro.kernels.dispatch import DispatchPolicy, select_kernel

    t0 = time.perf_counter()
    policy = policy or DispatchPolicy()
    cache = kernel_cache if kernel_cache is not None else KernelCache()
    buckets = _bucket_heights(plan, policy.bucket, bucket_registry)
    # band-coordinate ext of each live register, tracked only for the
    # masked (shard_origin) binding, which needs the global offset
    reg_boxes: Dict[str, Box] = {}

    regs = _SlotAllocator()
    bufs = _SlotAllocator()
    # (stencil, steps) -> (impl_name, callable); resolved once at lower time
    kernels: Dict[tuple, Tuple[str, Callable]] = {}
    nd_impls: set = set()               # "reference_nd" when box kernels bind
    # statically tracked codec context between a Compress and its transfer
    pending_h2d: Dict[str, str] = {}    # reg -> codec (non-identity, h2d)
    pending_d2h: Dict[str, str] = {}    # reg -> codec (non-identity, d2h)

    signatures = set()
    stages: List[List] = []             # [key, [BoundOp...]]
    chunk_ordinal = -1                  # index of the current chunk stage

    def emit(key, tag: str, fn: Callable, site=None) -> None:
        s = site if site is not None else key
        bound = (_TAG[tag], fn, s[0], s[1])
        if stages and stages[-1][0] == key and key is not None:
            stages[-1][1].append(bound)
        else:
            stages.append([key, [bound]])

    for op in plan.ops:
        if isinstance(op, HostCommit):
            def run_commit(rt, _r=op.round):
                rt.commit_round(_r)

            emit(None, "HostCommit", run_commit, site=(op.round, -1))
            continue
        key = (op.round, op.chunk)
        if not stages or stages[-1][0] != key:
            chunk_ordinal += 1
            regs.new_stage(chunk_ordinal)
            bufs.new_stage(chunk_ordinal)
        if isinstance(op, Compress):
            if op.direction == "h2d":
                codec = get_codec(op.codec)
                if codec.name == "identity":
                    # identity fast path: skip the encode/decode byte
                    # round trip — the H2D itself is the (pure) copy;
                    # wire-byte accounting stays plan-derived
                    emit(key, "Compress", _noop)
                else:
                    slot = regs.alloc(op.reg)   # H2D binds as the wire hop
                    pending_h2d[op.reg] = op.codec
                    sl = op.box.slices()

                    def run(rt, _s=slot, _sl=sl, _c=codec):
                        rows = rt.host[_sl]
                        rt.wire[_s] = (jnp.asarray(_c.encode(rows)),
                                       rows.shape, rows.dtype)

                    emit(key, "Compress", run)
            else:
                if op.codec != "identity":
                    pending_d2h[op.reg] = op.codec
                emit(key, "Compress", _noop)
        elif isinstance(op, Decompress):
            if op.direction == "h2d" and op.codec != "identity":
                slot = regs.get(op.reg)
                codec = get_codec(op.codec)

                def run(rt, _s=slot, _c=codec):
                    payload, shape, dtype = rt.wire.pop(_s)
                    rt.regs[_s] = jnp.asarray(
                        _c.decode(np.asarray(payload), shape, dtype))

                emit(key, "Decompress", run)
            else:
                # d2h decode runs at the HostCommit barrier (the first
                # point the device bytes are forced anyway)
                emit(key, "Decompress", _noop)
        elif isinstance(op, H2D):
            if shard_origin is not None:
                reg_boxes[op.reg] = op.box
            if op.reg in pending_h2d:
                # the wire hop already carried the encoded payload
                del pending_h2d[op.reg]
                emit(key, "H2D", _noop)
            else:
                slot = regs.alloc(op.reg)
                sl = op.box.slices()

                def run(rt, _s=slot, _sl=sl):
                    rt.regs[_s] = jnp.asarray(rt.host[_sl])

                emit(key, "H2D", run)
        elif isinstance(op, BufferWrite):
            rslot = regs.get(op.reg)
            bslot = bufs.alloc(op.buf)
            sl = op.reg_box.slices()

            def run(rt, _b=bslot, _r=rslot, _sl=sl):
                rt.bufs[_b] = rt.regs[_r][_sl]

            emit(key, "BufferWrite", run)
        elif isinstance(op, BufferRead):
            bslot = bufs.free(op.buf, chunk_ordinal)    # consumed exactly once
            src_slot = regs.free(op.src, chunk_ordinal)  # src dies here
            dst_slot = regs.alloc(op.reg)
            if shard_origin is not None:
                # the buffer's extent slices prepend at the low side
                sbox = reg_boxes.pop(op.src)
                reg_boxes[op.reg] = sbox.with_axis(
                    op.axis, sbox.lo[op.axis] - op.extent, sbox.hi[op.axis])

            def run(rt, _b=bslot, _src=src_slot, _dst=dst_slot, _ax=op.axis):
                shared = rt.bufs[_b]
                rt.bufs[_b] = None
                src = rt.regs[_src]
                if _src != _dst:
                    rt.regs[_src] = None
                rt.regs[_dst] = jnp.concatenate([shared, src], axis=_ax)

            emit(key, "BufferRead", run)
        elif isinstance(op, FusedKernel):
            slot = regs.get(op.reg)
            if shard_origin is not None:
                # hierarchical inner kernel: globally-masked update, one
                # signature per ext shape (origins are traced)
                signatures.add(("hier", op.stencil, op.steps, op.shape_in))
                nd_impls.add("masked_hier")
                emit(key, "FusedKernel",
                     _bind_kernel_masked(slot, op, reg_boxes[op.reg],
                                         shard_origin, cache, plan.itemsize))
                continue
            if not _is_banded(op):
                # N-D box band: reference kernel, one signature per
                # distinct (shape, keeps)
                signatures.add((op.stencil, op.steps, op.keep_lo,
                                op.keep_hi, op.shape_in))
                nd_impls.add("reference_nd")
                emit(key, "FusedKernel",
                     _bind_kernel_nd(slot, op, cache, plan.itemsize))
                continue
            kkey = (op.stencil, op.steps)
            if kkey not in kernels:
                if fused_step is not None:
                    kernels[kkey] = ("explicit", fused_step)
                else:
                    kernels[kkey] = select_kernel(op.stencil, op.steps, policy)
            impl_name, fn = kernels[kkey]
            gkey = (op.stencil, op.steps, op.keep_lo[0], op.keep_hi[0])
            bucket_h = buckets.get(gkey, op.shape_in[0])
            signatures.add(gkey + (bucket_h,))
            emit(key, "FusedKernel",
                 _bind_kernel(slot, op, bucket_h, impl_name, fn, cache,
                              plan.itemsize))
        elif isinstance(op, D2H):
            slot = regs.free(op.reg, chunk_ordinal)   # last use of the register
            if shard_origin is not None:
                reg_boxes.pop(op.reg, None)
            codec_name = pending_d2h.pop(op.reg, None)
            rsl, hsl = op.reg_box.slices(), op.box.slices()

            def run(rt, _s=slot, _rsl=rsl, _hsl=hsl, _codec=codec_name):
                band = rt.regs[_s]
                rt.regs[_s] = None
                rt.staged.append((_hsl, band[_rsl], _codec))

            emit(key, "D2H", run)
        else:  # pragma: no cover - planner/lowering version skew
            raise TypeError(f"unknown op {op!r}")

    impl_names = sorted({name for name, _ in kernels.values()} | nd_impls)
    lowered_stages = []
    for key, ops in stages:
        ops = tuple(ops)
        prefetch = tuple(
            b for b in ops
            if b[0] == _TAG["H2D"] or b[0] == _TAG["Compress"])
        rest = tuple(b for b in ops if b not in prefetch)
        lowered_stages.append(LoweredStage(key=key, ops=ops,
                                           prefetch=prefetch, rest=rest))
    return CompiledPlan(
        plan=plan,
        stages=tuple(lowered_stages),
        n_reg_slots=regs.n_slots,
        n_buf_slots=bufs.n_slots,
        kernel_impl="+".join(impl_names) if impl_names else "none",
        shape_buckets=len(signatures),
        cache=cache,
        lower_s=time.perf_counter() - t0,
    )


# --------------------------------------------------------------------------
# Sharded-plan lowering: per-rank streams -> global phase-ordered stage
# programs, executed in lockstep on a single device (the simulator behind
# repro.core.executor.ShardedSimExecutor).  Reuses the slot binder for
# rank bands and the KernelCache for the masked shard kernel — shards are
# uniform, so every rank and round shares ONE compiled signature (the
# per-rank global origin is a traced argument, not a static one).
# --------------------------------------------------------------------------


class _ShardRuntime:
    """Slot-indexed per-rank band state + the halo mailbox the bound
    closures run against.  ``mail`` is keyed ``(src, dst, axis, round)``
    — unique per exchange because each ordered rank pair swaps at most
    one payload per axis per round; with a halo codec the value is the
    encoded ``(payload, shape, dtype)`` wire triple instead of the raw
    slice.  ``slot_pool`` (optional) is the shared pool hierarchical
    inner plans lease their chunk-slot storage from."""

    __slots__ = ("host", "bands", "mail", "staged", "slot_pool")

    def __init__(self, host: np.ndarray, n_slots: int, slot_pool=None):
        self.host = host
        self.bands: List = [None] * n_slots
        self.mail: Dict[tuple, jnp.ndarray] = {}
        self.staged: List[tuple] = []   # (host slice tuple, device band)
        self.slot_pool = slot_pool

    def commit(self) -> None:
        for _, rows in self.staged:
            jax.block_until_ready(rows)
        for sl, rows in self.staged:
            self.host[sl] = np.asarray(rows)
        self.staged.clear()


@dataclasses.dataclass(frozen=True)
class ShardStage:
    """One global phase: every rank's bound ops, rank order.  Phase
    boundaries are the plan's barrier structure — an executor must drain
    a stage before starting the next (sends and recvs never share one)."""

    label: str
    ops: Tuple[BoundOp, ...]


def _bind_hier_kernel(slot: int, hk: int, inner) -> Callable:
    """Bind a ShardKernel to its expanded inner plan (hierarchical
    execution): the rank's halo-extended band becomes the inner plan's
    host domain, the nested stage programs stream it chunk-wise through
    the ordinary H2D/kernel/D2H path (leasing slot storage from the
    shared pool when one rides on the runtime), and the updated owned
    region is cropped back — exactly what the flat masked kernel's crop
    produces, because the inner kernels run the same globally-masked
    update on ext regions whose write-back depth equals the halo."""

    def run(rt):
        band = np.asarray(rt.bands[slot])
        host, _, _ = inner.execute(band, slot_pool=rt.slot_pool)
        rt.bands[slot] = jnp.asarray(
            host[hk:-hk, hk:-hk] if hk else host)

    return run


def _bind_shard_kernel(slot: int, op: ShardKernel, plan: ShardedPlan,
                       cache: KernelCache) -> Callable:
    from .distributed import masked_local_steps
    from .stencil import get_stencil

    st = get_stencil(op.stencil)
    hk = op.steps * st.radius
    # one signature per (stencil, steps, band shape, domain): gy0/gx0 are
    # traced, so all ranks and rounds hit the same compiled kernel
    key = ("shard", op.stencil, op.steps, op.h, op.w, plan.Y, plan.X,
           plan.itemsize)
    gy0, gx0 = op.gy0, op.gx0

    def make() -> Callable:
        def f(ext, y0, x0):
            out = masked_local_steps(ext, st, op.steps, y0, x0,
                                     plan.Y, plan.X)
            return out[hk:-hk, hk:-hk] if hk else out
        return jax.jit(f)

    def run(rt):
        fn = cache.lookup(key, make)
        rt.bands[slot] = fn(rt.bands[slot], gy0, gx0)

    return run


@dataclasses.dataclass
class CompiledShardedPlan:
    """A lowered :class:`~repro.core.plan.ShardedPlan`: phase-ordered
    stage programs of slot-bound closures over a shared halo mailbox."""

    plan: ShardedPlan
    stages: Tuple[ShardStage, ...]
    n_slots: int
    shape_buckets: int
    cache: KernelCache
    lower_s: float
    kernel_impl: str = "shard_sim"

    def describe(self) -> dict:
        return {
            "stage_count": len(self.stages),
            "shape_buckets": self.shape_buckets,
            "kernel_impl": self.kernel_impl,
            "reg_slots": self.n_slots,
            "buf_slots": 0,
        }

    def execute(self, x: np.ndarray, injector=None, retry=None,
                slot_pool: Optional[SlotPool] = None,
                ) -> Tuple[np.ndarray, TransferStats, ExecStats]:
        """Run every phase in barrier order (all ranks lockstep).  The
        result matches the shard_map backend to float tolerance — same
        masked-update math via :func:`repro.core.distributed
        .masked_local_steps` — and the returned stats are the
        plan-derived accounting, untouched by execution.

        ``injector``/``retry`` mirror :meth:`CompiledPlan.execute`, with
        the op site's chunk field addressing the *rank* — a
        ``rank_loss`` trigger at ``(round, rank)`` fires mid-round, after
        that round's loads/halos already moved (what a real preemption
        costs).  Sharded plans commit host state once at the end, so a
        terminal fault surfaces with ``last_committed_round = -1``; the
        elastic harness (:mod:`repro.launch.elastic`) recovers round
        granularity by executing one-round continuation plans.

        ``slot_pool`` is only consulted by hierarchical plans: each
        expanded ShardKernel leases its inner chunk-slot storage from
        the pool and releases it when the nested run retires (also on
        fault paths — the inner executor releases in ``finally``), so
        :meth:`SlotPool.assert_balanced` holds after any exit."""
        rt = _ShardRuntime(validate_domain(self.plan, x), self.n_slots,
                           slot_pool=slot_pool)
        wall = [0.0] * len(OP_TAGS)
        counts = [0] * len(OP_TAGS)
        hits0, miss0 = self.cache.hits, self.cache.misses
        f0 = injector.faults_injected if injector is not None else 0
        r0 = injector.retries if injector is not None else 0
        perf = time.perf_counter
        t_run = perf()
        try:
            for stage in self.stages:
                for tag, fn, rnd, rank in stage.ops:
                    if injector is not None:
                        consult(injector, retry, rnd, rank, OP_TAGS[tag])
                    t0 = perf()
                    fn(rt)
                    wall[tag] += perf() - t0
                    counts[tag] += 1
            rt.commit()
        except InjectedFault as f:
            from .recovery import PlanExecutionError, plan_fingerprint
            raise PlanExecutionError(
                f"sharded plan failed at round={f.round} rank={f.chunk} "
                f"op={f.op_class}: {f.kind}",
                fault=f, last_committed_round=-1,
                fingerprint=plan_fingerprint(self.plan)) from f
        stats = ExecStats(
            kernel_impl=self.kernel_impl,
            op_counts={OP_TAGS[i]: c for i, c in enumerate(counts) if c},
            op_wall_s={OP_TAGS[i]: wall[i] for i, c in enumerate(counts) if c},
            kernel_calls=counts[_TAG["ShardKernel"]],
            shape_buckets=self.shape_buckets,
            kernel_compiles=self.cache.misses - miss0,
            kernel_cache_hits=self.cache.hits - hits0,
            stage_count=len(self.stages),
            lower_s=self.lower_s,
            wall_s=perf() - t_run,
            faults_injected=(injector.faults_injected - f0)
            if injector is not None else 0,
            retries=(injector.retries - r0) if injector is not None else 0,
        )
        return rt.host, self.plan.stats(), stats


def lower_sharded(plan,
                  kernel_cache: Optional[KernelCache] = None,
                  ) -> CompiledShardedPlan:
    """Compile a sharded plan's per-rank streams into global stage
    programs.

    Each rank's evolving band (own -> row-extended -> fully-extended ->
    cropped own) binds to one slot via the same :class:`_SlotAllocator`
    the single-device lowering uses; halo ops become mailbox closures;
    :class:`~repro.core.plan.ShardKernel` ops dispatch through the keyed
    :class:`KernelCache` — uniform shards mean exactly one kernel
    signature for the whole plan (``shape_buckets == 1``).

    Accepts a :class:`~repro.core.hierarchy.HierarchicalPlan` too: the
    outer streams lower exactly as above, except each ShardKernel binds
    to its rank's nested inner plan — itself lowered through
    :func:`lower` in masked ``shard_origin`` mode, sharing this plan's
    :class:`KernelCache` so inner compiles surface in the same counters.

    A non-identity halo codec (``plan.codec``) runs for real: the
    ``HaloCompress`` closure slices the edge payload and encodes it —
    the mailbox then carries the encoded wire triple — and the paired
    ``HaloRecv`` decodes before attaching, so lossless codecs round-trip
    bit-exactly through actual encoded bytes while the accounting stays
    plan-derived.  The ``identity`` codec is fast-pathed (the raw slice
    is already the copy)."""
    t0 = time.perf_counter()
    hplan = None
    if not isinstance(plan, ShardedPlan) and hasattr(plan, "outer"):
        # HierarchicalPlan (duck-typed: hierarchy.py must stay importable
        # without this module)
        hplan = plan
        outer = plan.outer
    else:
        outer = plan
    if outer.trailing:
        raise ValueError(
            f"plan models trailing axes {outer.trailing}; trailing plans "
            "are dry-run-only (byte/flop accounting) and cannot execute")
    cache = kernel_cache if kernel_cache is not None else KernelCache()
    regs = _SlotAllocator()
    signatures = set()
    stages: List[ShardStage] = []
    hk = outer.k_ici * outer.radius

    halo_codec = None
    if outer.codec and outer.codec != "identity":
        halo_codec = get_codec(outer.codec)

    inner_compiled = {}
    if hplan is not None:
        for rank, sh in enumerate(outer.shards):
            origin = (sh.y0 - hk, sh.x0 - hk, outer.Y, outer.X)
            inner_compiled[rank] = lower(
                hplan.inner[rank], shard_origin=origin, kernel_cache=cache)
            # uniform shards -> every rank's inner plan presents the same
            # ext shapes, so the signature census dedupes across ranks
            for iop in hplan.inner[rank].ops:
                if isinstance(iop, FusedKernel):
                    signatures.add(("hier", iop.stencil, iop.steps,
                                    iop.shape_in))

    for ordinal, (label, ops) in enumerate(outer.phases()):
        regs.new_stage(ordinal)
        bound: List[BoundOp] = []
        for op in ops:
            if isinstance(op, ShardLoad):
                slot = regs.alloc(f"band:{op.rank}")
                sl = op.box.slices()

                def run(rt, _s=slot, _sl=sl):
                    rt.bands[_s] = jnp.asarray(rt.host[_sl])

                bound.append((_TAG["ShardLoad"], run, op.round, op.rank))
            elif isinstance(op, HaloCompress):
                if halo_codec is None:
                    bound.append((_TAG["HaloCompress"], _noop,
                                  op.round, op.rank))
                else:
                    # the encode IS the send: the mailbox carries the
                    # encoded wire triple instead of the raw edge slice
                    slot = regs.get(f"band:{op.rank}")
                    mkey = (op.rank, op.peer, op.axis, op.round)
                    axis, side = op.axis, op.side

                    def run(rt, _s=slot, _k=mkey, _a=axis, _e=side, _d=hk,
                            _c=halo_codec):
                        band = rt.bands[_s]
                        if _a == 0:
                            payload = band[-_d:] if _e == "hi" else band[:_d]
                        else:
                            payload = (band[:, -_d:] if _e == "hi"
                                       else band[:, :_d])
                        rows = np.asarray(payload)
                        rt.mail[_k] = (_c.encode(rows), rows.shape,
                                       rows.dtype)

                    bound.append((_TAG["HaloCompress"], run,
                                  op.round, op.rank))
            elif isinstance(op, HaloSend):
                if halo_codec is not None:
                    # wire hop already happened at the HaloCompress
                    bound.append((_TAG["HaloSend"], _noop,
                                  op.round, op.rank))
                    continue
                slot = regs.get(f"band:{op.rank}")
                mkey = (op.rank, op.dst, op.axis, op.round)
                axis, side, depth = op.axis, op.side, op.depth

                def run(rt, _s=slot, _k=mkey, _a=axis, _e=side, _d=depth):
                    band = rt.bands[_s]
                    if _a == 0:
                        payload = band[-_d:] if _e == "hi" else band[:_d]
                    else:
                        payload = band[:, -_d:] if _e == "hi" else band[:, :_d]
                    rt.mail[_k] = payload

                bound.append((_TAG["HaloSend"], run, op.round, op.rank))
            elif isinstance(op, HaloRecv):
                slot = regs.get(f"band:{op.rank}")
                mkey = (op.src, op.rank, op.axis, op.round)
                axis, side, depth, src = op.axis, op.side, op.depth, op.src

                def run(rt, _s=slot, _k=mkey, _a=axis, _e=side, _d=depth,
                        _src=src, _c=halo_codec):
                    band = rt.bands[_s]
                    if _src < 0:
                        # mesh edge: zero fill, exactly what ppermute
                        # leaves for non-receivers (masked, never read
                        # by valid cells)
                        shape = ((_d, band.shape[1]) if _a == 0
                                 else (band.shape[0], _d))
                        payload = jnp.zeros(shape, band.dtype)
                    elif _c is not None:
                        wire, shape, dtype = rt.mail.pop(_k)
                        payload = jnp.asarray(
                            _c.decode(np.asarray(wire), shape, dtype))
                    else:
                        payload = rt.mail.pop(_k)
                    pair = [payload, band] if _e == "lo" else [band, payload]
                    rt.bands[_s] = jnp.concatenate(pair, axis=_a)

                bound.append((_TAG["HaloRecv"], run, op.round, op.rank))
            elif isinstance(op, HaloDecompress):
                # decode runs at the paired HaloRecv (the payload must
                # materialize before it is concatenated anyway)
                bound.append((_TAG["HaloDecompress"], _noop,
                              op.round, op.rank))
            elif isinstance(op, ShardKernel):
                slot = regs.get(f"band:{op.rank}")
                if hplan is not None:
                    bound.append((_TAG["ShardKernel"],
                                  _bind_hier_kernel(
                                      slot, hk, inner_compiled[op.rank]),
                                  op.round, op.rank))
                    continue
                signatures.add((op.stencil, op.steps, op.h, op.w))
                bound.append((_TAG["ShardKernel"],
                              _bind_shard_kernel(slot, op, outer, cache),
                              op.round, op.rank))
            elif isinstance(op, ShardStore):
                slot = regs.free(f"band:{op.rank}", ordinal)
                sl = op.box.slices()

                def run(rt, _s=slot, _sl=sl):
                    band = rt.bands[_s]
                    rt.bands[_s] = None
                    rt.staged.append((_sl, band))

                bound.append((_TAG["ShardStore"], run, op.round, op.rank))
            else:  # pragma: no cover - planner/lowering version skew
                raise TypeError(f"unknown sharded op {op!r}")
        stages.append(ShardStage(label=label, ops=tuple(bound)))

    return CompiledShardedPlan(
        plan=plan,   # the hierarchical wrapper when given one: stats()
        stages=tuple(stages),     # must report both levels
        n_slots=regs.n_slots,
        shape_buckets=len(signatures),
        cache=cache,
        lower_s=time.perf_counter() - t0,
        kernel_impl="shard_sim+hier" if hplan is not None else "shard_sim",
    )
