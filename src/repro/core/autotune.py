"""Automatic run-time configuration selection (the paper's future work).

Sec. VII: "We also plan to refine the performance model which can be used
to automatically select the optimization target between kernel execution
and data transfer."  This module does exactly that: for a given stencil
code and hardware it enumerates the Sec. IV-C feasible set, *compiles the
candidate's full transfer/kernel op schedule* (a dry-run plan — exact
TransferStats geometry, zero engine execution, zero array allocation),
evaluates the Sec. III model over it, and returns the best
(engine, d, S_TB, k_on) with the predicted bottleneck.

Because the winning :class:`~repro.core.plan.ExecutionPlan` is the very
object the executors run, a selected config's measured accounting equals
its predicted accounting field-for-field — the sweep costs what execution
costs.

Because the model is evaluated per engine, the selector also answers the
paper's Fig. 3a question ("which term should we optimize?") automatically:
if the feasible set's best SO2DR config is transfer-bound, more TB steps
are pointless and it says so.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import warnings
from typing import Iterable, List, Optional, Sequence, Tuple

from .analytic import EngineTimes, Hardware, model_times
from .compress import compress_plan
from .executor import DryRunExecutor
from .oocore import compile_box_plan, compile_plan
from .params import CodeSpec, feasible
from .plan import (
    BufferRead, BufferWrite, Compress, D2H, ExecutionPlan, FusedKernel, H2D,
)
from .stencil import Stencil
from .tiling import split_steps

__all__ = ["Choice", "autotune", "optimization_target",
           "BoxChoice", "autotune_box", "trapezoid_redundant_elements",
           "ShardedChoice", "autotune_sharded",
           "StageCost", "stage_costs", "pipeline_makespan",
           "predicted_makespan", "predicted_sharded_makespan"]


@dataclasses.dataclass(frozen=True)
class Choice:
    engine: str
    d: int
    s_tb: int
    k_on: int
    codec: str               # transfer codec ("identity" = uncompressed)
    time_s: float
    bottleneck: str          # "transfer" | "kernel"
    times: EngineTimes
    kernel_impl: str = "pallas_db"   # dispatch-registry implementation
    tile: Optional[tuple] = None     # VMEM tile (None = impl default)

    @property
    def config(self):
        return dict(engine=self.engine, d=self.d, s_tb=self.s_tb,
                    k_on=self.k_on, codec=self.codec,
                    kernel_impl=self.kernel_impl, tile=self.tile)


def _bottleneck(t: EngineTimes, n_streams: int) -> str:
    return "transfer" if t.h2d + t.d2h >= t.kernel + t.odc else "kernel"


def _deprecated_tuner(old: str) -> None:
    warnings.warn(
        f"repro.core.autotune.{old}() is deprecated; use "
        f"repro.tune(repro.TuneSpec(...)) — one entry point for the row, "
        f"box and sharded sweeps, with profile-aware costing and measured "
        f"refinement", DeprecationWarning, stacklevel=3)


def _autotune(
    st: Stencil,
    sz: int,
    n_steps: int,
    hw: Hardware,
    engines: Iterable[str] = ("so2dr", "resreu"),
    d_grid: Iterable[int] = (4, 8, 16),
    s_tb_grid: Iterable[int] = (20, 40, 80, 160, 320, 640),
    k_on_grid: Iterable[int] = (1, 2, 4, 8),
    codecs: Iterable[str] = ("identity", "zrle"),
    kernel_impls: Iterable[str] = ("reference", "pallas", "pallas_db"),
    tile_grid: Iterable[Optional[tuple]] = (None,),
    b_elem: int = 4,
    profile=None,
) -> List[Choice]:
    """Rank all feasible configs by modeled overlapped time (best first).

    Codec choice sweeps alongside ``(d, S_TB, k_on)``: the base plan is
    compiled once per geometry and rewritten per codec (the rewrite is a
    cheap op-stream pass), then costed by the same dry-run executor —
    wire bytes drive the transfer terms, so a codec only wins when the
    config is transfer-bound.

    The kernel-dispatch policy sweeps too: every candidate's kernel term
    is re-evaluated per implementation in ``kernel_impls`` x VMEM tile in
    ``tile_grid`` (``None`` = the implementation's default tile) via
    :func:`repro.kernels.dispatch.modeled_kernel_time` — per-step HBM
    streaming for the reference path, tile-apron overhead and DMA/compute
    (non-)overlap for the Pallas paths.  Infeasible combinations (tile
    set exceeding the modeled VMEM, unsupported stencil) are skipped.
    The beyond-paper ``mxu`` recast is opt-in
    (``kernel_impls=(..., "mxu")``): it changes which compute unit the
    Sec. III model assumes, which the paper-faithful sweep should not do
    silently.

    The default codec grid is lossless-only: the model charges no
    accuracy cost, so a lossy codec like ``bf16`` would weakly dominate
    whenever any transfer time exists and the tuner would silently
    recommend re-quantizing numerics.  Callers who accept the bf16 error
    bound opt in with ``codecs=("identity", "zrle", "bf16")``."""
    from repro.kernels.dispatch import modeled_kernel_time

    code = CodeSpec(sz=sz, radius=st.radius, b_elem=b_elem,
                    total_steps=n_steps, n_arrays=2)
    Y = X = sz + 2 * st.radius
    out: List[Choice] = []
    for engine in engines:
        for d in d_grid:
            for s_tb in s_tb_grid:
                if s_tb > n_steps or not feasible(code, hw, d, s_tb):
                    continue
                k_ons = (1,) if engine == "resreu" else k_on_grid
                for k_on in k_ons:
                    try:
                        base = compile_plan(engine, st, Y, X, n_steps,
                                            d, s_tb, k_on, b_elem)
                    except ValueError:
                        continue
                    # kernel ops are codec-independent: model the
                    # (impl, tile) kernel terms once per geometry
                    kernel_terms = []
                    for impl in kernel_impls:
                        for tile in tile_grid:
                            kt = modeled_kernel_time(base, hw, impl, tile,
                                                     profile=profile)
                            if kt is not None:
                                kernel_terms.append((impl, tile, kt))
                    for codec in codecs:
                        try:
                            plan = compress_plan(base, codec)
                        except ValueError:
                            continue   # codec can't handle this itemsize
                        _, stats = DryRunExecutor().execute(plan)
                        t_base = model_times(stats, hw)
                        for impl, tile, (k_s, mem_s, cmp_s) in kernel_terms:
                            t = dataclasses.replace(
                                t_base, kernel=k_s, kernel_mem=mem_s,
                                kernel_compute=cmp_s)
                            out.append(Choice(
                                engine=engine, d=d, s_tb=s_tb, k_on=k_on,
                                codec=codec,
                                time_s=t.total_overlapped(hw.n_streams),
                                bottleneck=_bottleneck(t, hw.n_streams),
                                times=t,
                                kernel_impl=impl, tile=tile,
                            ))
    out.sort(key=lambda c: c.time_s)
    return out


def autotune(*args, **kwargs) -> List[Choice]:
    """Deprecated alias of the row-plan sweep — use :func:`repro.tune`."""
    _deprecated_tuner("autotune")
    return _autotune(*args, **kwargs)


autotune.__doc__ = (autotune.__doc__ or "") + "\n\n" + (_autotune.__doc__ or "")


@dataclasses.dataclass(frozen=True)
class BoxChoice:
    """One ranked BoxTB configuration: tile grid x time depth (+ codec)."""

    tiles: Tuple[int, ...]
    time_depth: int
    k_on: int
    codec: str
    time_s: float
    bottleneck: str          # "transfer" | "kernel"
    times: EngineTimes
    redundant_elements: int  # trapezoid-apron overcompute, plan-derived
    redundancy: float        # redundant / exact

    @property
    def config(self):
        return dict(engine="box_tb", tiles=self.tiles,
                    time_depth=self.time_depth, k_on=self.k_on,
                    codec=self.codec)


def trapezoid_redundant_elements(st: Stencil, shape: Sequence[int],
                                 n_steps: int, tiles: Sequence[int],
                                 time_depth: int) -> int:
    """Closed-form redundant element-updates of a BoxTB schedule.

    Each round of ``k`` steps computes, per tile and per step ``s``
    (counting down, ``s = k-1`` last), an interior box whose extent along
    axis ``a`` is ``e_a + (k-1-s) * c_a * r`` where ``e_a`` is the tile's
    owned interior extent and ``c_a`` counts the tile's non-frame sides
    on that axis (0, 1, or 2) — the trapezoid: the apron starts ``k*r``
    deep per open side and loses ``r`` per step until only the owned box
    remains.  Summing the box volumes over steps, tiles, and rounds and
    subtracting the exact count ``n * prod(S_a - 2r)`` gives the
    redundancy the plan's :class:`~repro.core.plan.TransferStats` must
    report (property-tested in ``tests/test_box_tb.py``)."""
    r = st.radius
    nd = len(shape)
    tiles = tuple(int(t) for t in tiles) + (1,) * (nd - len(tiles))
    if len(tiles) != nd:
        raise ValueError(f"tiles {tiles} over-ranks shape {tuple(shape)}")
    sizes = []   # per-axis near-even interior split (same as make_chunk_plan)
    for a in range(nd):
        interior, d = shape[a] - 2 * r, tiles[a]
        sizes.append([interior // d + (1 if i < interior % d else 0)
                      for i in range(d)])
    computed = 0
    for k in split_steps(n_steps, time_depth):
        for multi in itertools.product(*(range(t) for t in tiles)):
            base = [sizes[a][multi[a]] for a in range(nd)]
            open_sides = [(multi[a] != 0) + (multi[a] != tiles[a] - 1)
                          for a in range(nd)]
            for s in range(k):
                computed += math.prod(
                    base[a] + (k - 1 - s) * open_sides[a] * r
                    for a in range(nd))
    exact = n_steps * math.prod(s - 2 * r for s in shape)
    return computed - exact


def _autotune_box(
    st: Stencil,
    shape: Sequence[int],
    n_steps: int,
    hw: Hardware,
    tile_grid: Iterable[Sequence[int]] = ((1, 1), (2, 2), (4, 4)),
    time_depth_grid: Iterable[int] = (1, 2, 4),
    k_on_grid: Iterable[int] = (1,),
    codecs: Iterable[str] = ("identity",),
    b_elem: int = 4,
) -> List[BoxChoice]:
    """Rank BoxTB tile grids x time depths by modeled overlapped time
    (best first) — the box-plan companion of :func:`autotune`.

    Every candidate compiles its full :class:`~repro.core.plan.
    ExecutionPlan` via :func:`~repro.core.oocore.compile_box_plan`
    (infeasible geometry — an apron deeper than the smallest tile — is
    skipped exactly like the row sweep skips infeasible ``k_off``),
    rewrites it per codec, and is costed by the dry-run executor +
    Sec. III model.  The trade the ranking exposes: deeper ``time_depth``
    divides the H2D/D2H rounds by ``t`` while the trapezoid aprons grow
    the kernel term by the redundancy reported per choice — the N-D
    out-of-core analogue of the sharded engine's ``k_ici`` sweep."""
    out: List[BoxChoice] = []
    for tiles in tile_grid:
        for t in time_depth_grid:
            for k_on in k_on_grid:
                try:
                    base = compile_box_plan(st, shape, n_steps, tiles, t,
                                            k_on=k_on, itemsize=b_elem)
                except ValueError:
                    continue
                for codec in codecs:
                    try:
                        plan = compress_plan(base, codec)
                    except ValueError:
                        continue   # codec can't handle this itemsize
                    _, stats = DryRunExecutor().execute(plan)
                    tm = model_times(stats, hw)
                    out.append(BoxChoice(
                        tiles=tuple(int(x) for x in tiles), time_depth=t,
                        k_on=k_on, codec=codec,
                        time_s=tm.total_overlapped(hw.n_streams),
                        bottleneck=_bottleneck(tm, hw.n_streams),
                        times=tm,
                        redundant_elements=stats.redundant_elements,
                        redundancy=stats.redundancy))
    out.sort(key=lambda c: c.time_s)
    return out


def autotune_box(*args, **kwargs) -> List[BoxChoice]:
    """Deprecated alias of the BoxTB sweep — use :func:`repro.tune`."""
    _deprecated_tuner("autotune_box")
    return _autotune_box(*args, **kwargs)


autotune_box.__doc__ = (autotune_box.__doc__ or "") + "\n\n" + (
    _autotune_box.__doc__ or "")


@dataclasses.dataclass(frozen=True)
class ShardedChoice:
    """One ranked L2 configuration: mesh decomposition + halo depth
    (+ halo codec)."""

    mesh: Tuple[int, int]
    k_ici: int
    time_s: float
    bottleneck: str          # "ici" | "kernel"
    ici_s: float
    kernel_s: float
    ici_bytes: int           # total send-side ICI payload (raw)
    redundancy: float        # plan-derived ghost-wedge overhead
    codec: str = "identity"  # halo codec ("identity" = raw exchange)
    ici_wire_bytes: int = 0  # total send-side ICI payload on the wire

    @property
    def config(self):
        return dict(mesh=self.mesh, k_ici=self.k_ici, codec=self.codec)


def _autotune_sharded(
    st: Stencil,
    Y: int,
    n_steps: int,
    hw: Hardware,
    n_devices: int = 8,
    k_ici_grid: Iterable[int] = (1, 2, 4, 8),
    codecs: Iterable[str] = ("identity",),
    b_elem: int = 4,
) -> List[ShardedChoice]:
    """Rank mesh decomposition x ``k_ici`` for the L2 sharded engine
    (best first) — the inter-chip companion of :func:`autotune`.

    Every factorization of ``n_devices`` into a ``(rows, cols)`` mesh is
    swept against the ``k_ici`` grid; each candidate compiles its full
    :class:`~repro.core.plan.ShardedPlan` (infeasible geometry —
    indivisible domain, halo deeper than a shard, ``n % k_ici`` — is
    skipped exactly like the L1 sweep skips infeasible ``k_off``) and is
    costed from the plan-derived stats alone:

    * ICI time charges the max per-rank send bytes per round — *wire*
      bytes, so a halo codec shrinks this term — at ``bw_ici`` plus
      ``t_ici_latency`` per collective phase (two per round on a 2-D
      mesh) — the latency term is what makes the paper's trade visible:
      larger ``k_ici`` buys ``1/k`` fewer exchange phases for a
      near-constant per-step byte cost;
    * kernel time is the per-rank roofline over the max rank (ghost
      wedges included), so deeper halos pay their redundant compute.

    ``codecs`` sweeps the halo codec alongside ``(mesh, k_ici)``: the
    base plan is compiled once per geometry and rewritten per codec by
    :func:`~repro.core.compress.compress_plan` (which learns the
    collective vocabulary on sharded plans), so ``ici_wire_bytes``
    replaces ``ici_bytes`` in the bandwidth term while a non-identity
    codec is charged one extra ``t_ici_latency`` per exchange phase for
    its encode/decode stage — zrle/bf16 halos only win when the config
    is latency-tolerant and bandwidth-bound.  The default grid is
    identity-only for the same reason the row sweep's is lossless-only:
    the model charges no accuracy cost.

    The two phases do not overlap in the exchange-then-compute schedule,
    so the total is their sum.  The per-device schedule knobs
    ``(d, S_TB, k_on, codec)`` stay orthogonal: compose this sweep with
    :func:`autotune` to pick the on-device plan each rank runs.

    ``Y`` is the *global framed* domain side (the sharded planner takes
    the full shape directly — mesh divisibility is part of feasibility).
    """
    from .shard import compile_sharded

    if hw.bw_ici <= 0:
        raise ValueError(f"hardware {hw.name!r} has no modeled ICI bandwidth")
    out: List[ShardedChoice] = []
    for n_row in range(1, n_devices + 1):
        if n_devices % n_row:
            continue
        mesh = (n_row, n_devices // n_row)
        for k_ici in k_ici_grid:
            try:
                base = compile_sharded(st.name, Y, Y, n_steps, k_ici, mesh,
                                       itemsize=b_elem)
            except ValueError:
                continue
            phases = (mesh[0] > 1) + (mesh[1] > 1)   # row + col exchanges
            # kernel ops are codec-independent: roofline once per geometry
            per = [base.per_rank_stats(r) for r in range(base.n_ranks)]
            k_mem = max(p.kernel_hbm_bytes for p in per) / hw.bw_dmem
            k_cmp = max(p.flops for p in per) / hw.peak_vpu_flops
            kernel_s = max(k_mem, k_cmp)
            for codec in codecs:
                try:
                    plan = (base if codec == "identity"
                            else compress_plan(base, codec))
                except ValueError:
                    continue   # codec can't handle this itemsize
                _, stats = DryRunExecutor().execute(plan)
                # a non-identity codec stages encode/decode around each
                # exchange phase: one extra latency charge per phase
                lat = phases * hw.t_ici_latency * (2 if codec != "identity"
                                                   else 1)
                ici_s = plan.rounds * (
                    lat + plan.collective_wire_bytes_per_round / hw.bw_ici)
                out.append(ShardedChoice(
                    mesh=mesh, k_ici=k_ici, time_s=ici_s + kernel_s,
                    bottleneck="ici" if ici_s >= kernel_s else "kernel",
                    ici_s=ici_s, kernel_s=kernel_s,
                    ici_bytes=stats.ici_bytes, redundancy=stats.redundancy,
                    codec=codec, ici_wire_bytes=stats.ici_wire_bytes))
    out.sort(key=lambda c: c.time_s)
    return out


def autotune_sharded(*args, **kwargs) -> List[ShardedChoice]:
    """Deprecated alias of the L2 sharded sweep — use :func:`repro.tune`."""
    _deprecated_tuner("autotune_sharded")
    return _autotune_sharded(*args, **kwargs)


autotune_sharded.__doc__ = (autotune_sharded.__doc__ or "") + "\n\n" + (
    _autotune_sharded.__doc__ or "")


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Modeled resource demand of one ``(round, chunk)`` stage program.

    ``key is None`` marks a HostCommit barrier stage — zero demand, but
    a scheduling fence: the owning job's next H2D cannot start before
    every staged write of that job has drained."""

    key: Optional[Tuple[int, int]]
    h2d_s: float       # interconnect in  (wire bytes / bw_intc)
    d2h_s: float       # interconnect out (wire bytes / bw_intc)
    compute_s: float   # kernel roofline + on-device buffer copies


def stage_costs(plan: ExecutionPlan, hw: Hardware) -> List[StageCost]:
    """Cost every stage of ``plan`` under the Sec. III model.

    Transfers are charged at *wire* bytes (a ``Compress`` op adjusts its
    wrapped transfer by ``wire - raw``); BufferRead/Write traffic rides
    the HBM bus, so it lands in the compute term alongside the kernel
    roofline — exactly the resource split
    :meth:`EngineTimes.total_overlapped` assumes, but per stage instead
    of per plan, which is what lets a scheduler reason about *inter-job*
    overlap."""
    out: List[StageCost] = []
    for key, ops in plan.stages():
        if key is None:
            out.append(StageCost(None, 0.0, 0.0, 0.0))
            continue
        h2d = d2h = 0
        compute = 0.0
        for op in ops:
            if isinstance(op, H2D):
                h2d += op.nbytes
            elif isinstance(op, D2H):
                d2h += op.nbytes
            elif isinstance(op, Compress):
                delta = op.wire_nbytes - op.raw_nbytes
                if op.direction == "h2d":
                    h2d += delta
                else:
                    d2h += delta
            elif isinstance(op, (BufferWrite, BufferRead)):
                compute += op.nbytes / hw.bw_dmem
            elif isinstance(op, FusedKernel):
                compute += max(op.hbm_bytes / hw.bw_dmem,
                               op.flops / hw.peak_vpu_flops)
        out.append(StageCost(key, h2d / hw.bw_intc, d2h / hw.bw_intc,
                             compute))
    return out


def pipeline_makespan(schedule: Iterable[Tuple[object, StageCost]]) -> float:
    """Makespan of a stage schedule on the three-engine machine.

    ``schedule`` is ``(job, StageCost)`` in issue order — possibly an
    interleaving of several jobs.  The machine is the paper's
    ``N_strm = 3`` pipeline: one H2D engine, one compute engine, one D2H
    engine, each serially ordered, a stage flowing H2D -> compute -> D2H.
    Barrier stages (``key is None``) model HostCommit: the owning job's
    next H2D waits until all of that job's staged writes have drained.
    Interleaving wins exactly when one job's transfer hides under
    another job's compute — idle engine time a single job cannot fill.
    """
    h2d_free = comp_free = d2h_free = 0.0
    commit: dict = {}    # job -> host rows ready (last barrier drain time)
    staged: dict = {}    # job -> drain time of its latest staged D2H
    t_end = 0.0
    for job, sc in schedule:
        if sc.key is None:
            t = staged.get(job, commit.get(job, 0.0))
            commit[job] = t
            t_end = max(t_end, t)
            continue
        start = max(h2d_free, commit.get(job, 0.0))
        h2d_free = start + sc.h2d_s
        comp_free = max(comp_free, h2d_free) + sc.compute_s
        d2h_free = max(d2h_free, comp_free) + sc.d2h_s
        staged[job] = d2h_free
        t_end = max(t_end, d2h_free)
    return t_end


def predicted_makespan(plan: ExecutionPlan, hw: Hardware) -> float:
    """Modeled solo makespan of one plan on the three-engine pipeline.

    The dry-run cost the serving layer's deadline-aware admission sorts
    on: no device work, no arrays — stage geometry in, seconds out."""
    return pipeline_makespan((0, sc) for sc in stage_costs(plan, hw))


def predicted_sharded_makespan(plan, hw: Hardware) -> float:
    """Modeled makespan of one sharded (or hierarchical) plan: the ICI
    exchange term plus the per-rank kernel roofline, priced exactly like
    one :func:`autotune_sharded` candidate.

    The ICI term charges *wire* bytes — a halo codec on the plan shrinks
    it, at the cost of one extra ``t_ici_latency`` per exchange phase
    for the encode/decode stage.  For a hierarchical plan the per-rank
    stats already roll the nested streaming program up, so the inner
    H2D/D2H traffic rides the kernel term's memory side the same way
    the sharded sweep sees ghost-wedge redundancy."""
    if hw.bw_ici <= 0:
        raise ValueError(f"hardware {hw.name!r} has no modeled ICI bandwidth")
    mesh = plan.mesh_shape
    phases = (mesh[0] > 1) + (mesh[1] > 1)
    codec = getattr(plan, "codec", "")
    lat = phases * hw.t_ici_latency * (2 if codec not in ("", "identity")
                                       else 1)
    ici_s = plan.rounds * (
        lat + plan.collective_wire_bytes_per_round / hw.bw_ici)
    per = [plan.per_rank_stats(r) for r in range(plan.n_ranks)]
    k_mem = max(p.kernel_hbm_bytes + p.h2d_wire_bytes + p.d2h_wire_bytes
                + p.buffer_bytes for p in per) / hw.bw_dmem
    k_cmp = max(p.flops for p in per) / hw.peak_vpu_flops
    return ici_s + max(k_mem, k_cmp)


def optimization_target(st: Stencil, sz: int, n_steps: int,
                        hw: Hardware) -> Optional[str]:
    """The paper's Fig. 3a decision, automated: what should be optimized
    next for the *best* config — 'kernel' or 'transfer'?

    Evaluated on uncompressed plans (the paper's setting): a transfer
    codec would shrink the wire term and skew the very comparison this
    reproduces.  Sweep ``autotune(..., codecs=...)`` directly to ask the
    codec-aware question."""
    ranked = _autotune(st, sz, n_steps, hw, codecs=("identity",))
    return ranked[0].bottleneck if ranked else None
