"""Typed op IR for out-of-core stencil schedules (plan/execute split).

Every engine in :mod:`repro.core.oocore` is a *planner*: it compiles
``(domain shape, stencil, d, k_off, k_on, n)`` into an
:class:`ExecutionPlan` — a flat sequence of ops over named device
*registers* (working bands) and named device *buffers* (region-sharing
carries).  The executors in :mod:`repro.core.executor` then interpret the
same plan eagerly, software-pipelined, or as a zero-device dry run.

Op vocabulary (the paper's Fig. 7 cost categories map 1:1 onto op types):

=============  =============================================  ===========
op             semantics                                      Fig. 7 bar
=============  =============================================  ===========
H2D            ``reg = host[host_lo:host_hi]``                HtoD
BufferWrite    ``buffer[buf] = reg[reg_lo:reg_hi]``           O/D copy
BufferRead     ``reg = concat(buffer[buf], reg[src])``        O/D copy
FusedKernel    ``reg = fused_step(reg, steps, keeps)``        Kernel
D2H            stage ``reg[reg_lo:reg_hi] -> host rows``      DtoH
HostCommit     flush staged D2H rows into the host array      (barrier)
Compress       encode the wrapped transfer's payload          HtoD/DtoH
Decompress     decode it on the other side of the wire        HtoD/DtoH
=============  =============================================  ===========

``Compress``/``Decompress`` are transfer *transformations*
(arXiv 2204.11315): the rewrite pass in :mod:`repro.core.compress` wraps
every ``H2D``/``D2H`` in an encode/decode pair carrying the codec id,
the raw byte count, and the modeled wire byte count, so the dry-run
executor costs compressed schedules exactly like uncompressed ones.

Each op carries its exact byte count and ``(round, chunk)`` provenance, so
:meth:`ExecutionPlan.stats` derives the full :class:`TransferStats` —
h2d/d2h/buffer/kernel bytes, FLOPs, redundancy — from the plan alone,
with zero device work.  That is what lets the autotuner cost the whole
``(d, k_off, k_on)`` sweep analytically and what keeps the measured and
predicted accounting equal *by construction*.

``HostCommit`` is the only ordering barrier an executor must respect:
ops between two commits may be reordered/overlapped as long as
register/buffer data dependencies hold (the double-buffered executor
exploits exactly this to prefetch chunk ``i+1``'s H2D under chunk ``i``'s
kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple, Union

__all__ = [
    "TransferStats",
    "H2D", "D2H", "BufferWrite", "BufferRead", "FusedKernel", "HostCommit",
    "Compress", "Decompress",
    "Op", "ExecutionPlan", "PlanBuilder",
    "DeviceShard", "HaloSend", "HaloRecv", "ShardLoad", "ShardStore",
    "ShardKernel", "ShardOp", "ShardedPlan",
]


@dataclasses.dataclass
class TransferStats:
    """Byte/FLOP accounting for one engine run (paper Fig. 7 categories).

    ``*_bytes`` are the *raw* (uncompressed) transfer payloads — the row
    geometry the planner scheduled.  ``*_wire_bytes`` are what actually
    crosses the interconnect: equal to raw on uncompressed plans, and the
    codec-encoded sizes on plans rewritten by
    :func:`repro.core.compress.compress_plan` (arXiv 2204.11315-style
    on-the-fly transfer compression)."""

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    h2d_wire_bytes: int = 0     # interconnect bytes after codec encoding
    d2h_wire_bytes: int = 0
    codec_ops: int = 0          # Compress + Decompress op count
    buffer_bytes: int = 0       # on-device region-sharing copies ("O/D")
    ici_bytes: int = 0          # inter-chip halo payload (send side)
    halo_ops: int = 0           # HaloSend + paired HaloRecv op count
    kernel_calls: int = 0
    kernel_hbm_bytes: int = 0   # per-call band read + output write traffic
    flops: int = 0
    elements_computed: int = 0  # element-updates incl. redundant ones
    exact_elements: int = 0     # n * interior elements (the useful work)

    @property
    def redundant_elements(self) -> int:
        return self.elements_computed - self.exact_elements

    @property
    def redundancy(self) -> float:
        return self.redundant_elements / max(self.exact_elements, 1)

    @property
    def transfer_bytes(self) -> int:
        """Raw H2D + D2H payload (codec-independent row geometry)."""
        return self.h2d_bytes + self.d2h_bytes

    @property
    def wire_bytes(self) -> int:
        """H2D + D2H bytes that actually cross the interconnect."""
        return self.h2d_wire_bytes + self.d2h_wire_bytes

    @property
    def compression_ratio(self) -> float:
        """wire / raw — 1.0 for uncompressed plans, < 1.0 when a codec
        shrinks the transfers."""
        return self.wire_bytes / max(self.transfer_bytes, 1)

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals (the paper's Fig. 7 bars plus the
        L2 ``ici`` category) — one key set for every plan type."""
        return {
            "h2d": self.h2d_bytes,
            "d2h": self.d2h_bytes,
            "h2d_wire": self.h2d_wire_bytes,
            "d2h_wire": self.d2h_wire_bytes,
            "odc": self.buffer_bytes,
            "ici": self.ici_bytes,   # 0 for single-device plans
            "kernel_hbm": self.kernel_hbm_bytes,
        }


@dataclasses.dataclass(frozen=True)
class H2D:
    """Load host rows ``[host_lo, host_hi)`` into register ``reg``."""

    reg: str
    host_lo: int
    host_hi: int
    nbytes: int
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class D2H:
    """Stage register rows ``[reg_lo, reg_hi)`` for host rows
    ``[host_lo, host_hi)``; visible on host after the next HostCommit.
    The register is dead afterwards (planners emit D2H as its last use)."""

    reg: str
    reg_lo: int
    reg_hi: int
    host_lo: int
    host_hi: int
    nbytes: int
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class BufferWrite:
    """On-device copy of register rows ``[reg_lo, reg_hi)`` into the named
    region-sharing buffer ``buf`` (paper: the O/D traffic of Alg. 1 l. 6 /
    Fig. 2b's shared regions)."""

    buf: str
    reg: str
    reg_lo: int
    reg_hi: int
    nbytes: int
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class BufferRead:
    """``reg = concat(buffer[buf], reg[src])`` — consume a shared region
    (each buffer is written once and read exactly once, by the next
    chunk)."""

    reg: str
    buf: str
    src: str
    nbytes: int      # bytes of the buffer rows read
    rows: int        # buffer rows prepended
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class FusedKernel:
    """``steps`` fused stencil steps on register ``reg`` (in place).

    Carries the full kernel-phase accounting, precomputed at plan time:
    the compute area shrinks by ``r`` per step on non-frame sides, HBM
    traffic is one input-band read + one output-band write."""

    reg: str
    stencil: str
    steps: int
    keep_top: bool
    keep_bottom: bool
    h_in: int
    h_out: int
    width: int
    hbm_bytes: int
    flops: int
    elements: int    # element-updates incl. redundant ones
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class _CodecOp:
    """Shared shape of the encode/decode halves of a wrapped transfer.

    Both halves carry the same provenance — the codec id, the raw and
    modeled-wire byte counts, and the wrapped ``H2D``/``D2H``'s register
    and host-row range — so :func:`repro.core.compress.compress_plan`
    builds one metadata dict and instantiates the pair from it.
    ``wire_nbytes`` is the codec's analytic ratio model — deterministic
    at plan time, so accounting stays a property of the plan."""

    codec: str
    reg: str
    direction: str   # "h2d" | "d2h"
    raw_nbytes: int
    wire_nbytes: int
    host_lo: int     # wrapped transfer's host-row provenance
    host_hi: int
    round: int
    chunk: int


@dataclasses.dataclass(frozen=True)
class Compress(_CodecOp):
    """Encode the payload of the adjacent wrapped transfer.

    Emitted by :func:`repro.core.compress.compress_plan` immediately
    *before* the ``H2D``/``D2H`` it wraps.  For ``direction == "h2d"``
    the encode runs host-side (the wire then carries ``wire_nbytes``);
    for ``"d2h"`` it runs device-side before the staging copy."""


@dataclasses.dataclass(frozen=True)
class Decompress(_CodecOp):
    """Decode the wrapped transfer's payload on the far side of the wire.

    Emitted immediately *after* the wrapped ``H2D``/``D2H``: device-side
    for ``"h2d"`` (the register materializes here), host-side for
    ``"d2h"`` (the staged rows are decoded at the ``HostCommit``
    barrier)."""


@dataclasses.dataclass(frozen=True)
class HostCommit:
    """Flush all staged D2H writes to the host array.

    A scheduling barrier: ops must not be moved across it (NaiveTB's
    ping-pong host state relies on round ``t+1`` reading pre-commit rows
    of round ``t``)."""

    nbytes: int      # staged bytes flushed by this commit
    round: int


Op = Union[H2D, D2H, BufferWrite, BufferRead, FusedKernel, HostCommit,
           Compress, Decompress]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A compiled transfer/kernel schedule for one engine configuration."""

    engine: str
    stencil: str
    Y: int
    X: int
    itemsize: int
    n: int
    d: int
    k_off: int
    k_on: int
    exact_elements: int
    ops: Tuple[Op, ...]
    codec: str = ""     # "" = uncompressed; else the wrapping codec's name

    def __iter__(self) -> Iterator[Op]:
        return iter(self.ops)

    def __len__(self) -> int:
        return len(self.ops)

    def stats(self) -> TransferStats:
        """Derive the complete :class:`TransferStats` from the op stream.

        This is the single source of truth for accounting: the dry-run
        executor returns it untouched, and the eager/double-buffered
        executors return it alongside the computed domain."""
        s = TransferStats(exact_elements=self.exact_elements)
        for op in self.ops:
            if isinstance(op, H2D):
                s.h2d_bytes += op.nbytes
                s.h2d_wire_bytes += op.nbytes
            elif isinstance(op, D2H):
                s.d2h_bytes += op.nbytes
                s.d2h_wire_bytes += op.nbytes
            elif isinstance(op, (BufferWrite, BufferRead)):
                s.buffer_bytes += op.nbytes
            elif isinstance(op, FusedKernel):
                s.kernel_calls += 1
                s.kernel_hbm_bytes += op.hbm_bytes
                s.flops += op.flops
                s.elements_computed += op.elements
            elif isinstance(op, Compress):
                # the wrapped transfer contributed raw bytes to the wire
                # accumulator above; the codec swaps them for wire bytes
                s.codec_ops += 1
                if op.direction == "h2d":
                    s.h2d_wire_bytes += op.wire_nbytes - op.raw_nbytes
                else:
                    s.d2h_wire_bytes += op.wire_nbytes - op.raw_nbytes
            elif isinstance(op, Decompress):
                s.codec_ops += 1
        return s

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals (the paper's Fig. 7 bars) read
        directly off the op stream."""
        return self.stats().breakdown()

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for op in self.ops:
            k = type(op).__name__
            out[k] = out.get(k, 0) + 1
        return out

    def stages(self) -> List[Tuple[Optional[Tuple[int, int]], List[Op]]]:
        """Group ops into pipeline stages.

        Returns ``[(key, ops), ...]`` where ``key`` is ``(round, chunk)``
        for chunk work and ``None`` for a HostCommit barrier.  Stage order
        equals plan order; the double-buffered executor prefetches the
        next stage's H2D ops while the current stage's kernels are in
        flight, never crossing a barrier."""
        out: List[Tuple[Optional[Tuple[int, int]], List[Op]]] = []
        for op in self.ops:
            if isinstance(op, HostCommit):
                out.append((None, [op]))
                continue
            key = (op.round, op.chunk)
            if out and out[-1][0] == key:
                out[-1][1].append(op)
            else:
                out.append((key, [op]))
        return out


# --------------------------------------------------------------------------
# Sharded plans (L2 / inter-chip): per-device op streams + halo exchange.
#
# The L2 engine in :mod:`repro.core.distributed` trades redundant
# ghost-wedge computation for k_ici-step communication-avoiding halo
# exchange — the paper's core trade one memory level up.  The IR below
# makes that schedule a first-class plan: a :class:`ShardedPlan` holds one
# op stream per :class:`DeviceShard` plus a global barrier structure
# (``barriers``), and its accounting — ICI bytes, ghost-wedge redundancy,
# collective bytes per round — is derived from the op streams exactly
# like :class:`TransferStats` is derived from an :class:`ExecutionPlan`.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceShard:
    """Provenance of one device's sub-domain in a sharded plan.

    ``(row, col)`` are mesh coordinates; ``[y0, y1) x [x0, x1)`` is the
    owned region of the global framed domain (uniform across ranks — the
    shard_map backend requires even divisibility)."""

    rank: int
    row: int
    col: int
    y0: int
    y1: int
    x0: int
    x1: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.y1 - self.y0, self.x1 - self.x0)


@dataclasses.dataclass(frozen=True)
class ShardLoad:
    """Place the shard's owned region on its device (the once-per-run
    H2D of the L2 schedule — the domain then stays resident)."""

    rank: int
    y0: int
    y1: int
    x0: int
    x1: int
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class ShardStore:
    """Stage the shard's owned region back to the host (committed at the
    final barrier)."""

    rank: int
    y0: int
    y1: int
    x0: int
    x1: int
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class HaloSend:
    """Send ``depth`` edge rows/columns of this rank's band to ``dst``.

    ``axis`` 0 exchanges rows of the owned band; ``axis`` 1 exchanges
    columns of the *row-extended* band (corners ride along — the
    ppermute ordering of :mod:`repro.core.distributed`).  ``side`` names
    the edge of the sender's band: ``"hi"`` (bottom/right) payloads
    attach at the receiver's ``"lo"`` (top/left) edge and vice versa.
    ``nbytes`` is the send-side ICI payload."""

    rank: int        # src shard
    dst: int         # dst shard
    axis: int        # 0 = rows, 1 = columns
    side: str        # "lo" | "hi" — sender's edge
    depth: int       # k_ici * r rows/cols
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class HaloRecv:
    """Attach a neighbour's halo payload at this rank's ``side`` edge.

    ``src == -1`` marks a mesh edge: the band is zero-padded instead
    (exactly what ``ppermute`` leaves for non-receivers) and no ICI
    traffic occurs (``nbytes == 0``).  Every real recv (``src >= 0``)
    pairs 1:1 with a :class:`HaloSend` in the source rank's stream."""

    rank: int        # dst shard (owner of this stream)
    src: int         # src shard; -1 = mesh edge (zero fill)
    axis: int
    side: str        # "lo" | "hi" — receiver's edge
    depth: int
    nbytes: int      # 0 when src == -1
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class ShardKernel:
    """``steps`` fused, globally-masked stencil steps on the extended
    band, cropped back to the owned region.

    The band covers ``[gy0, gy0+h) x [gx0, gx0+w)`` in global
    coordinates (origin = owned region minus the ``k_ici*r`` halo).
    ``elements`` counts every updated element per round — the owned
    interior *plus* the redundant ghost wedges; ``hbm_bytes`` is one
    band read + one band write per fused call, mirroring
    :func:`fused_kernel_geometry`'s model."""

    rank: int
    stencil: str
    steps: int
    gy0: int
    gx0: int
    h: int
    w: int
    hbm_bytes: int
    flops: int
    elements: int
    round: int
    phase: int


ShardOp = Union[ShardLoad, ShardStore, HaloSend, HaloRecv, ShardKernel]


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """A compiled multi-device schedule: one op stream per shard.

    ``barriers`` is the global barrier structure: a tuple of phase
    labels; every op's ``phase`` indexes into it, and an executor must
    run phase ``p`` of *every* stream before any op of phase ``p+1``
    (within a phase, rank order is free — sends and recvs live in
    separate phases, so the lockstep is deadlock-free by construction).
    """

    stencil: str
    Y: int
    X: int
    itemsize: int
    n: int
    k_ici: int
    mesh_shape: Tuple[int, int]
    radius: int
    shards: Tuple[DeviceShard, ...]
    streams: Tuple[Tuple[ShardOp, ...], ...]
    barriers: Tuple[str, ...]
    exact_elements: int

    @property
    def n_ranks(self) -> int:
        return len(self.shards)

    @property
    def rounds(self) -> int:
        return self.n // self.k_ici

    def __len__(self) -> int:
        return sum(len(s) for s in self.streams)

    def _accumulate(self, s: "TransferStats", ops) -> "TransferStats":
        for op in ops:
            if isinstance(op, ShardLoad):
                s.h2d_bytes += op.nbytes
                s.h2d_wire_bytes += op.nbytes
            elif isinstance(op, ShardStore):
                s.d2h_bytes += op.nbytes
                s.d2h_wire_bytes += op.nbytes
            elif isinstance(op, HaloSend):
                s.ici_bytes += op.nbytes
                s.halo_ops += 1
            elif isinstance(op, HaloRecv):
                if op.src >= 0:
                    s.halo_ops += 1
            elif isinstance(op, ShardKernel):
                s.kernel_calls += 1
                s.kernel_hbm_bytes += op.hbm_bytes
                s.flops += op.flops
                s.elements_computed += op.elements
            else:  # pragma: no cover - planner/IR version skew
                raise TypeError(f"unknown sharded op {op!r}")
        return s

    def stats(self) -> TransferStats:
        """Aggregate :class:`TransferStats` over every rank's stream —
        the single source of truth for the sharded accounting, derived
        from the plan with zero device work (the dry-run executor
        returns it untouched)."""
        s = TransferStats(exact_elements=self.exact_elements)
        for stream in self.streams:
            self._accumulate(s, stream)
        return s

    def per_rank_stats(self, rank: int) -> TransferStats:
        """One rank's accounting; ``exact_elements`` is the rank's share
        (``n x`` its owned-interior elements)."""
        sh = self.shards[rank]
        r = self.radius
        rows = max(0, min(sh.y1, self.Y - r) - max(sh.y0, r))
        cols = max(0, min(sh.x1, self.X - r) - max(sh.x0, r))
        s = TransferStats(exact_elements=self.n * rows * cols)
        return self._accumulate(s, self.streams[rank])

    def ici_bytes_per_round(self, rank: int) -> int:
        """Plan-derived send-side ICI bytes one rank pushes per round
        (uniform across rounds — round 0 is read off the stream)."""
        return sum(op.nbytes for op in self.streams[rank]
                   if isinstance(op, HaloSend) and op.round == 0)

    @property
    def collective_bytes_per_round(self) -> int:
        """Per-rank ICI bytes per round, derived from the op streams
        (max over ranks).  For a rank with neighbours on both sides of
        both mesh axes this equals the analytic formula in
        :func:`repro.core.distributed.collective_bytes_per_round`; edge
        ranks push less (no payload crosses a mesh boundary)."""
        return max((self.ici_bytes_per_round(r) for r in range(self.n_ranks)),
                   default=0)

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals — the Fig. 7 bars plus the L2 ICI
        category (same keys as :meth:`ExecutionPlan.breakdown`)."""
        return self.stats().breakdown()

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for stream in self.streams:
            for op in stream:
                k = type(op).__name__
                out[k] = out.get(k, 0) + 1
        return out

    def phases(self) -> List[Tuple[str, List[ShardOp]]]:
        """Ops grouped by global phase, in barrier order (rank order
        within a phase) — the structure executors walk."""
        out: List[Tuple[str, List[ShardOp]]] = [
            (label, []) for label in self.barriers]
        for stream in self.streams:
            for op in stream:
                out[op.phase][1].append(op)
        return out


def fused_kernel_geometry(
    radius: int, flops_per_elem: int, h: int, X: int, steps: int,
    keep_top: bool, keep_bottom: bool, itemsize: int,
) -> Tuple[int, int, int, int]:
    """Accounting for one fused kernel call.

    Returns ``(h_out, hbm_bytes, flops, elements)``: the band shrinks by
    ``r`` rows per step on each non-frame side; HBM traffic is one read of
    the input band plus one write of the output band."""
    keep = (int(keep_top) + int(keep_bottom)) * radius
    r = radius
    h_in = h
    flops = 0
    elements = 0
    for _ in range(steps):
        rows = h - 2 * r
        elements += rows * (X - 2 * r)
        flops += rows * (X - 2 * r) * flops_per_elem
        h = rows + keep
    hbm_bytes = (h_in + h) * X * itemsize
    return h, hbm_bytes, flops, elements


class PlanBuilder:
    """Validating builder the engine planners drive.

    Tracks register/buffer heights so every emitted op's byte count and
    geometry are consistent; catches planner bugs (reading an unwritten
    buffer, double-reading a carry, kernel on a dead register) at compile
    time instead of at execution time."""

    def __init__(self, engine: str, stencil, Y: int, X: int, n: int,
                 d: int, k_off: int, k_on: int, itemsize: int):
        self.engine = engine
        self.st = stencil
        self.Y, self.X = Y, X
        self.n, self.d, self.k_off, self.k_on = n, d, k_off, k_on
        self.itemsize = itemsize
        self.ops: List[Op] = []
        self._reg_h: Dict[str, int] = {}      # live register -> rows
        self._buf_h: Dict[str, int] = {}      # unread buffer -> rows
        self._staged_bytes = 0
        self._codec = None                    # set by with_compression()

    def with_compression(self, codec) -> "PlanBuilder":
        """Attach a transfer codec (name or :class:`~repro.core.compress.Codec`).

        Chainable; :meth:`build` then rewrites the finished schedule with
        :func:`repro.core.compress.compress_plan`, wrapping every
        ``H2D``/``D2H`` in a ``Compress``/``Decompress`` pair.  Planners
        stay codec-oblivious: the same engine code emits compressed and
        uncompressed schedules."""
        self._codec = codec
        return self

    def _row_bytes(self, rows: int) -> int:
        return rows * self.X * self.itemsize

    def height(self, reg: str) -> int:
        """Current rows of a live register (planners use it to address
        slices relative to the evolving band)."""
        return self._reg_h[reg]

    def h2d(self, reg: str, host_lo: int, host_hi: int, rnd: int, chunk: int) -> None:
        assert 0 <= host_lo < host_hi <= self.Y, (host_lo, host_hi)
        assert reg not in self._reg_h, f"register {reg!r} already live"
        self._reg_h[reg] = host_hi - host_lo
        self.ops.append(H2D(reg, host_lo, host_hi,
                            self._row_bytes(host_hi - host_lo), rnd, chunk))

    def buffer_write(self, buf: str, reg: str, reg_lo: int, reg_hi: int,
                     rnd: int, chunk: int) -> None:
        h = self._reg_h[reg]
        assert 0 <= reg_lo < reg_hi <= h, (reg_lo, reg_hi, h)
        assert buf not in self._buf_h, f"buffer {buf!r} written twice"
        self._buf_h[buf] = reg_hi - reg_lo
        self.ops.append(BufferWrite(buf, reg, reg_lo, reg_hi,
                                    self._row_bytes(reg_hi - reg_lo), rnd, chunk))

    def buffer_read(self, reg: str, buf: str, src: str, rnd: int, chunk: int) -> None:
        rows = self._buf_h.pop(buf)   # each shared region is consumed once
        src_h = self._reg_h.pop(src)
        self._reg_h[reg] = rows + src_h
        self.ops.append(BufferRead(reg, buf, src, self._row_bytes(rows),
                                   rows, rnd, chunk))

    def fused_kernel(self, reg: str, steps: int, keep_top: bool,
                     keep_bottom: bool, rnd: int, chunk: int) -> None:
        h = self._reg_h[reg]
        h_out, hbm, flops, elems = fused_kernel_geometry(
            self.st.radius, self.st.flops_per_elem, h, self.X, steps,
            keep_top, keep_bottom, self.itemsize)
        self._reg_h[reg] = h_out
        self.ops.append(FusedKernel(reg, self.st.name, steps, keep_top,
                                    keep_bottom, h, h_out, self.X, hbm,
                                    flops, elems, rnd, chunk))

    def d2h(self, reg: str, reg_lo: int, reg_hi: int, host_lo: int,
            host_hi: int, rnd: int, chunk: int) -> None:
        h = self._reg_h.pop(reg)      # last use: the register dies here
        assert 0 <= reg_lo < reg_hi <= h, (reg_lo, reg_hi, h)
        assert reg_hi - reg_lo == host_hi - host_lo
        nbytes = self._row_bytes(reg_hi - reg_lo)
        self._staged_bytes += nbytes
        self.ops.append(D2H(reg, reg_lo, reg_hi, host_lo, host_hi,
                            nbytes, rnd, chunk))

    def commit(self, rnd: int) -> None:
        self.ops.append(HostCommit(self._staged_bytes, rnd))
        self._staged_bytes = 0

    def build(self) -> ExecutionPlan:
        assert not self._reg_h, f"leaked registers: {sorted(self._reg_h)}"
        assert not self._buf_h, f"unread buffers: {sorted(self._buf_h)}"
        assert self._staged_bytes == 0, "uncommitted D2H rows at end of plan"
        r = self.st.radius
        exact = self.n * (self.Y - 2 * r) * (self.X - 2 * r)
        plan = ExecutionPlan(
            engine=self.engine, stencil=self.st.name, Y=self.Y, X=self.X,
            itemsize=self.itemsize, n=self.n, d=self.d, k_off=self.k_off,
            k_on=self.k_on, exact_elements=exact, ops=tuple(self.ops),
        )
        if self._codec is not None:
            from .compress import compress_plan   # local: avoids import cycle
            plan = compress_plan(plan, self._codec)
        return plan
