"""Typed op IR for out-of-core stencil schedules (plan/execute split).

Every engine in :mod:`repro.core.oocore` is a *planner*: it compiles
``(domain shape, stencil, d, k_off, k_on, n)`` into an
:class:`ExecutionPlan` — a flat sequence of ops over named device
*registers* (working bands) and named device *buffers* (region-sharing
carries).  The executors in :mod:`repro.core.executor` then interpret the
same plan eagerly, software-pipelined, or as a zero-device dry run.

Coordinates are **boxes**: every transfer/kernel op carries an N-D
:class:`Box` (per-axis ``[lo, hi)`` intervals over the framed domain), so
the same IR expresses classic row-range streaming (a 1-axis box over a
2-D domain), column chunking (``chunk_axis=1``), and 3-D tile plans with
temporal blocking.  Byte and element accounting derive from box volumes,
so the old row plans compile to bit-identical schedules as the
degenerate 1-axis case.

Op vocabulary (the paper's Fig. 7 cost categories map 1:1 onto op types):

=============  =============================================  ===========
op             semantics                                      Fig. 7 bar
=============  =============================================  ===========
H2D            ``reg = host[box]``                            HtoD
BufferWrite    ``buffer[buf] = reg[reg_box]``                 O/D copy
BufferRead     ``reg = concat(buffer[buf], reg[src], axis)``  O/D copy
FusedKernel    ``reg = fused_step(reg, steps, keeps)``        Kernel
D2H            stage ``reg[reg_box] -> host[box]``            DtoH
HostCommit     flush staged D2H boxes into the host array     (barrier)
Compress       encode the wrapped transfer's payload          HtoD/DtoH
Decompress     decode it on the other side of the wire        HtoD/DtoH
=============  =============================================  ===========

``Compress``/``Decompress`` are transfer *transformations*
(arXiv 2204.11315): the rewrite pass in :mod:`repro.core.compress` wraps
every ``H2D``/``D2H`` in an encode/decode pair carrying the codec id,
the raw byte count, and the modeled wire byte count, so the dry-run
executor costs compressed schedules exactly like uncompressed ones.

Each op carries its exact byte count and ``(round, chunk)`` provenance, so
:meth:`ExecutionPlan.stats` derives the full :class:`TransferStats` —
h2d/d2h/buffer/kernel bytes, FLOPs, redundancy — from the plan alone,
with zero device work.  That is what lets the autotuner cost the whole
``(d, k_off, k_on)`` (and tile box x time depth) sweep analytically and
what keeps the measured and predicted accounting equal *by construction*.

``HostCommit`` is the only ordering barrier an executor must respect:
ops between two commits may be reordered/overlapped as long as
register/buffer data dependencies hold (the double-buffered executor
exploits exactly this to prefetch chunk ``i+1``'s H2D under chunk ``i``'s
kernels).

The row-range accessors of the pre-box IR (``host_lo``/``host_hi``,
``reg_lo``/``reg_hi``, ``keep_top``/``keep_bottom``, ``h_in``/``h_out``/
``width``, ``rows``) survive as read-only properties delegating to the
op's box on the 1-axis case; they emit :class:`DeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Box", "TransferStats",
    "H2D", "D2H", "BufferWrite", "BufferRead", "FusedKernel", "HostCommit",
    "Compress", "Decompress",
    "Op", "ExecutionPlan", "PlanBuilder",
    "fused_kernel_geometry", "fused_box_geometry",
    "DeviceShard", "HaloSend", "HaloRecv", "ShardLoad", "ShardStore",
    "ShardKernel", "HaloCompress", "HaloDecompress", "ShardOp",
    "ShardedPlan",
]


def _deprecated(name: str, instead: str):
    warnings.warn(
        f"{name} is deprecated; read the op's {instead} instead",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class Box:
    """An N-D half-open interval product: ``[lo[a], hi[a])`` per axis.

    The coordinate type of the plan IR.  Immutable and hashable; all
    helpers return new boxes.  A classic row range ``[lo, hi)`` over a
    framed ``(Y, X)`` domain is the degenerate 1-axis box
    ``Box((lo, 0), (hi, X))``."""

    lo: Tuple[int, ...]
    hi: Tuple[int, ...]

    def __post_init__(self):
        lo, hi = tuple(self.lo), tuple(self.hi)
        object.__setattr__(self, "lo", lo)
        object.__setattr__(self, "hi", hi)
        if len(lo) != len(hi):
            raise ValueError(f"rank mismatch: lo={lo} hi={hi}")
        if any(a > b for a, b in zip(lo, hi)):
            raise ValueError(f"empty/negative box: lo={lo} hi={hi}")

    @classmethod
    def from_shape(cls, shape: Sequence[int]) -> "Box":
        """The full-domain box ``[0, shape[a])`` per axis."""
        return cls(tuple(0 for _ in shape), tuple(shape))

    @classmethod
    def span(cls, shape: Sequence[int], axis: int, lo: int, hi: int) -> "Box":
        """A box covering ``[lo, hi)`` along ``axis`` and the full extent
        of ``shape`` elsewhere — the degenerate 1-axis chunk."""
        los = [0] * len(shape)
        his = list(shape)
        los[axis], his[axis] = lo, hi
        return cls(tuple(los), tuple(his))

    @property
    def ndim(self) -> int:
        return len(self.lo)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.lo, self.hi))

    @property
    def volume(self) -> int:
        return math.prod(self.shape)

    def extent(self, axis: int) -> int:
        return self.hi[axis] - self.lo[axis]

    def slices(self) -> Tuple[slice, ...]:
        """Index tuple selecting this box out of a domain-shaped array."""
        return tuple(slice(a, b) for a, b in zip(self.lo, self.hi))

    def with_axis(self, axis: int, lo: int, hi: int) -> "Box":
        los, his = list(self.lo), list(self.hi)
        los[axis], his[axis] = lo, hi
        return Box(tuple(los), tuple(his))

    def shrink(self, lo_by: Sequence[int], hi_by: Sequence[int]) -> "Box":
        """Shrink per axis by ``lo_by[a]`` at the low side and
        ``hi_by[a]`` at the high side (negative values grow)."""
        return Box(tuple(a + d for a, d in zip(self.lo, lo_by)),
                   tuple(b - d for b, d in zip(self.hi, hi_by)))

    def clip(self, outer: "Box") -> "Box":
        """Intersect with ``outer`` (must be non-empty)."""
        return Box(tuple(max(a, oa) for a, oa in zip(self.lo, outer.lo)),
                   tuple(min(b, ob) for b, ob in zip(self.hi, outer.hi)))

    def translate(self, offset: Sequence[int]) -> "Box":
        return Box(tuple(a + o for a, o in zip(self.lo, offset)),
                   tuple(b + o for b, o in zip(self.hi, offset)))

    def contains(self, other: "Box") -> bool:
        return all(a <= oa and ob <= b for a, oa, ob, b in
                   zip(self.lo, other.lo, other.hi, self.hi))


@dataclasses.dataclass
class TransferStats:
    """Byte/FLOP accounting for one engine run (paper Fig. 7 categories).

    ``*_bytes`` are the *raw* (uncompressed) transfer payloads — the box
    geometry the planner scheduled.  ``*_wire_bytes`` are what actually
    crosses the interconnect: equal to raw on uncompressed plans, and the
    codec-encoded sizes on plans rewritten by
    :func:`repro.core.compress.compress_plan` (arXiv 2204.11315-style
    on-the-fly transfer compression)."""

    h2d_bytes: int = 0
    d2h_bytes: int = 0
    h2d_wire_bytes: int = 0     # interconnect bytes after codec encoding
    d2h_wire_bytes: int = 0
    codec_ops: int = 0          # Compress + Decompress op count
    buffer_bytes: int = 0       # on-device region-sharing copies ("O/D")
    ici_bytes: int = 0          # inter-chip halo payload (send side)
    ici_wire_bytes: int = 0     # ICI bytes after halo codec encoding
    halo_ops: int = 0           # HaloSend + paired HaloRecv op count
    kernel_calls: int = 0
    kernel_hbm_bytes: int = 0   # per-call band read + output write traffic
    flops: int = 0
    elements_computed: int = 0  # element-updates incl. redundant ones
    exact_elements: int = 0     # n * interior elements (the useful work)

    @property
    def redundant_elements(self) -> int:
        return self.elements_computed - self.exact_elements

    @property
    def redundancy(self) -> float:
        return self.redundant_elements / max(self.exact_elements, 1)

    @property
    def transfer_bytes(self) -> int:
        """Raw H2D + D2H payload (codec-independent box geometry)."""
        return self.h2d_bytes + self.d2h_bytes

    @property
    def wire_bytes(self) -> int:
        """H2D + D2H bytes that actually cross the interconnect."""
        return self.h2d_wire_bytes + self.d2h_wire_bytes

    @property
    def compression_ratio(self) -> float:
        """wire / raw — 1.0 for uncompressed plans, < 1.0 when a codec
        shrinks the transfers."""
        return self.wire_bytes / max(self.transfer_bytes, 1)

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals (the paper's Fig. 7 bars plus the
        L2 ``ici`` category) — one key set for every plan type."""
        return {
            "h2d": self.h2d_bytes,
            "d2h": self.d2h_bytes,
            "h2d_wire": self.h2d_wire_bytes,
            "d2h_wire": self.d2h_wire_bytes,
            "odc": self.buffer_bytes,
            "ici": self.ici_bytes,   # 0 for single-device plans
            "ici_wire": self.ici_wire_bytes,
            "kernel_hbm": self.kernel_hbm_bytes,
        }


@dataclasses.dataclass(frozen=True)
class H2D:
    """Load host box ``box`` into register ``reg``."""

    reg: str
    box: Box
    nbytes: int
    round: int
    chunk: int

    @property
    def host_lo(self) -> int:
        _deprecated("H2D.host_lo", "box.lo")
        return self.box.lo[0]

    @property
    def host_hi(self) -> int:
        _deprecated("H2D.host_hi", "box.hi")
        return self.box.hi[0]


@dataclasses.dataclass(frozen=True)
class D2H:
    """Stage register box ``reg_box`` (register-relative coordinates) for
    host box ``box``; visible on host after the next HostCommit.  The
    register is dead afterwards (planners emit D2H as its last use)."""

    reg: str
    reg_box: Box     # relative to the register's current band
    box: Box         # absolute host coordinates
    nbytes: int
    round: int
    chunk: int

    @property
    def reg_lo(self) -> int:
        _deprecated("D2H.reg_lo", "reg_box.lo")
        return self.reg_box.lo[0]

    @property
    def reg_hi(self) -> int:
        _deprecated("D2H.reg_hi", "reg_box.hi")
        return self.reg_box.hi[0]

    @property
    def host_lo(self) -> int:
        _deprecated("D2H.host_lo", "box.lo")
        return self.box.lo[0]

    @property
    def host_hi(self) -> int:
        _deprecated("D2H.host_hi", "box.hi")
        return self.box.hi[0]


@dataclasses.dataclass(frozen=True)
class BufferWrite:
    """On-device copy of register box ``reg_box`` (register-relative)
    into the named region-sharing buffer ``buf`` (paper: the O/D traffic
    of Alg. 1 l. 6 / Fig. 2b's shared regions)."""

    buf: str
    reg: str
    reg_box: Box     # relative to the register's current band
    nbytes: int
    round: int
    chunk: int

    @property
    def reg_lo(self) -> int:
        _deprecated("BufferWrite.reg_lo", "reg_box.lo")
        return self.reg_box.lo[0]

    @property
    def reg_hi(self) -> int:
        _deprecated("BufferWrite.reg_hi", "reg_box.hi")
        return self.reg_box.hi[0]


@dataclasses.dataclass(frozen=True)
class BufferRead:
    """``reg = concat(buffer[buf], reg[src], axis)`` — consume a shared
    region (each buffer is written once and read exactly once, by the
    next chunk).  The buffer's ``extent`` slices are prepended at the low
    side of ``axis``."""

    reg: str
    buf: str
    src: str
    nbytes: int      # bytes of the buffer slices read
    axis: int        # concatenation axis
    extent: int      # buffer extent along ``axis``
    round: int
    chunk: int

    @property
    def rows(self) -> int:
        _deprecated("BufferRead.rows", "extent")
        return self.extent


@dataclasses.dataclass(frozen=True)
class FusedKernel:
    """``steps`` fused stencil steps on register ``reg`` (in place).

    Carries the full kernel-phase accounting, precomputed at plan time:
    the compute volume shrinks by ``r`` per step on every non-frame side
    (``keep_lo``/``keep_hi`` per axis), HBM traffic is one input-band
    read + one output-band write."""

    reg: str
    stencil: str
    steps: int
    keep_lo: Tuple[bool, ...]    # per axis: low-side frame kept
    keep_hi: Tuple[bool, ...]    # per axis: high-side frame kept
    shape_in: Tuple[int, ...]
    shape_out: Tuple[int, ...]
    hbm_bytes: int
    flops: int
    elements: int    # element-updates incl. redundant ones
    round: int
    chunk: int

    @property
    def keep_top(self) -> bool:
        _deprecated("FusedKernel.keep_top", "keep_lo")
        return self.keep_lo[0]

    @property
    def keep_bottom(self) -> bool:
        _deprecated("FusedKernel.keep_bottom", "keep_hi")
        return self.keep_hi[0]

    @property
    def h_in(self) -> int:
        _deprecated("FusedKernel.h_in", "shape_in")
        return self.shape_in[0]

    @property
    def h_out(self) -> int:
        _deprecated("FusedKernel.h_out", "shape_out")
        return self.shape_out[0]

    @property
    def width(self) -> int:
        _deprecated("FusedKernel.width", "shape_in")
        return math.prod(self.shape_in[1:])


@dataclasses.dataclass(frozen=True)
class _CodecOp:
    """Shared shape of the encode/decode halves of a wrapped transfer.

    Both halves carry the same provenance — the codec id, the raw and
    modeled-wire byte counts, and the wrapped ``H2D``/``D2H``'s register
    and host box — so :func:`repro.core.compress.compress_plan` builds
    one metadata dict and instantiates the pair from it.
    ``wire_nbytes`` is the codec's analytic ratio model — deterministic
    at plan time, so accounting stays a property of the plan."""

    codec: str
    reg: str
    direction: str   # "h2d" | "d2h"
    raw_nbytes: int
    wire_nbytes: int
    box: Box         # wrapped transfer's host-box provenance
    round: int
    chunk: int

    @property
    def host_lo(self) -> int:
        _deprecated(f"{type(self).__name__}.host_lo", "box.lo")
        return self.box.lo[0]

    @property
    def host_hi(self) -> int:
        _deprecated(f"{type(self).__name__}.host_hi", "box.hi")
        return self.box.hi[0]


@dataclasses.dataclass(frozen=True)
class Compress(_CodecOp):
    """Encode the payload of the adjacent wrapped transfer.

    Emitted by :func:`repro.core.compress.compress_plan` immediately
    *before* the ``H2D``/``D2H`` it wraps.  For ``direction == "h2d"``
    the encode runs host-side (the wire then carries ``wire_nbytes``);
    for ``"d2h"`` it runs device-side before the staging copy."""


@dataclasses.dataclass(frozen=True)
class Decompress(_CodecOp):
    """Decode the wrapped transfer's payload on the far side of the wire.

    Emitted immediately *after* the wrapped ``H2D``/``D2H``: device-side
    for ``"h2d"`` (the register materializes here), host-side for
    ``"d2h"`` (the staged box is decoded at the ``HostCommit``
    barrier)."""


@dataclasses.dataclass(frozen=True)
class HostCommit:
    """Flush all staged D2H writes to the host array.

    A scheduling barrier: ops must not be moved across it (temporal
    blocking's ping-pong host state relies on round ``t+1`` reading
    pre-commit boxes of round ``t``)."""

    nbytes: int      # staged bytes flushed by this commit
    round: int


Op = Union[H2D, D2H, BufferWrite, BufferRead, FusedKernel, HostCommit,
           Compress, Decompress]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A compiled transfer/kernel schedule for one engine configuration.

    ``shape`` is the framed N-D host domain; ``chunk_axis`` is the
    streaming axis of 1-axis plans; ``tiles`` (per-axis tile counts) is
    non-empty for multi-axis box plans (``d == prod(tiles)``).  ``k_off``
    doubles as the temporal-blocking time depth ``t`` — the number of
    time steps advanced per H2D round trip."""

    engine: str
    stencil: str
    shape: Tuple[int, ...]
    itemsize: int
    n: int
    d: int
    k_off: int
    k_on: int
    exact_elements: int
    ops: Tuple[Op, ...]
    codec: str = ""     # "" = uncompressed; else the wrapping codec's name
    chunk_axis: int = 0
    tiles: Tuple[int, ...] = ()

    @property
    def Y(self) -> int:
        """First-axis extent (rows of a 2-D domain)."""
        return self.shape[0]

    @property
    def X(self) -> int:
        """Last-axis extent (columns of a 2-D domain)."""
        return self.shape[-1]

    def __iter__(self) -> Iterator[Op]:
        return iter(self.ops)

    def __len__(self) -> int:
        return len(self.ops)

    def stats(self) -> TransferStats:
        """Derive the complete :class:`TransferStats` from the op stream.

        This is the single source of truth for accounting: the dry-run
        executor returns it untouched, and the eager/double-buffered
        executors return it alongside the computed domain."""
        s = TransferStats(exact_elements=self.exact_elements)
        for op in self.ops:
            if isinstance(op, H2D):
                s.h2d_bytes += op.nbytes
                s.h2d_wire_bytes += op.nbytes
            elif isinstance(op, D2H):
                s.d2h_bytes += op.nbytes
                s.d2h_wire_bytes += op.nbytes
            elif isinstance(op, (BufferWrite, BufferRead)):
                s.buffer_bytes += op.nbytes
            elif isinstance(op, FusedKernel):
                s.kernel_calls += 1
                s.kernel_hbm_bytes += op.hbm_bytes
                s.flops += op.flops
                s.elements_computed += op.elements
            elif isinstance(op, Compress):
                # the wrapped transfer contributed raw bytes to the wire
                # accumulator above; the codec swaps them for wire bytes
                s.codec_ops += 1
                if op.direction == "h2d":
                    s.h2d_wire_bytes += op.wire_nbytes - op.raw_nbytes
                else:
                    s.d2h_wire_bytes += op.wire_nbytes - op.raw_nbytes
            elif isinstance(op, Decompress):
                s.codec_ops += 1
        return s

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals (the paper's Fig. 7 bars) read
        directly off the op stream."""
        return self.stats().breakdown()

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for op in self.ops:
            k = type(op).__name__
            out[k] = out.get(k, 0) + 1
        return out

    def stages(self) -> List[Tuple[Optional[Tuple[int, int]], List[Op]]]:
        """Group ops into pipeline stages.

        Returns ``[(key, ops), ...]`` where ``key`` is ``(round, chunk)``
        for chunk work and ``None`` for a HostCommit barrier.  Stage order
        equals plan order; the double-buffered executor prefetches the
        next stage's H2D ops while the current stage's kernels are in
        flight, never crossing a barrier."""
        out: List[Tuple[Optional[Tuple[int, int]], List[Op]]] = []
        for op in self.ops:
            if isinstance(op, HostCommit):
                out.append((None, [op]))
                continue
            key = (op.round, op.chunk)
            if out and out[-1][0] == key:
                out[-1][1].append(op)
            else:
                out.append((key, [op]))
        return out


# --------------------------------------------------------------------------
# Sharded plans (L2 / inter-chip): per-device op streams + halo exchange.
#
# The L2 engine in :mod:`repro.core.distributed` trades redundant
# ghost-wedge computation for k_ici-step communication-avoiding halo
# exchange — the paper's core trade one memory level up.  The IR below
# makes that schedule a first-class plan: a :class:`ShardedPlan` holds one
# op stream per :class:`DeviceShard` plus a global barrier structure
# (``barriers``), and its accounting — ICI bytes, ghost-wedge redundancy,
# collective bytes per round — is derived from the op streams exactly
# like :class:`TransferStats` is derived from an :class:`ExecutionPlan`.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceShard:
    """Provenance of one device's sub-domain in a sharded plan.

    ``(row, col)`` are mesh coordinates; ``[y0, y1) x [x0, x1)`` is the
    owned region of the global framed domain (uniform across ranks — the
    shard_map backend requires even divisibility)."""

    rank: int
    row: int
    col: int
    y0: int
    y1: int
    x0: int
    x1: int

    @property
    def box(self) -> Box:
        """The owned region as a :class:`Box` (the plan IR's coordinate
        type — ShardLoad/ShardStore carry the same box)."""
        return Box((self.y0, self.x0), (self.y1, self.x1))

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.y1 - self.y0, self.x1 - self.x0)


class _ShardRegionOp:
    """Deprecated scalar accessors shared by ShardLoad/ShardStore."""

    @property
    def y0(self) -> int:
        _deprecated(f"{type(self).__name__}.y0", "box.lo")
        return self.box.lo[0]

    @property
    def y1(self) -> int:
        _deprecated(f"{type(self).__name__}.y1", "box.hi")
        return self.box.hi[0]

    @property
    def x0(self) -> int:
        _deprecated(f"{type(self).__name__}.x0", "box.lo")
        return self.box.lo[1]

    @property
    def x1(self) -> int:
        _deprecated(f"{type(self).__name__}.x1", "box.hi")
        return self.box.hi[1]


@dataclasses.dataclass(frozen=True)
class ShardLoad(_ShardRegionOp):
    """Place the shard's owned region on its device (the once-per-run
    H2D of the L2 schedule — the domain then stays resident)."""

    rank: int
    box: Box
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class ShardStore(_ShardRegionOp):
    """Stage the shard's owned region back to the host (committed at the
    final barrier)."""

    rank: int
    box: Box
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class HaloSend:
    """Send ``depth`` edge rows/columns of this rank's band to ``dst``.

    ``axis`` 0 exchanges rows of the owned band; ``axis`` 1 exchanges
    columns of the *row-extended* band (corners ride along — the
    ppermute ordering of :mod:`repro.core.distributed`).  ``side`` names
    the edge of the sender's band: ``"hi"`` (bottom/right) payloads
    attach at the receiver's ``"lo"`` (top/left) edge and vice versa.
    ``nbytes`` is the send-side ICI payload."""

    rank: int        # src shard
    dst: int         # dst shard
    axis: int        # 0 = rows, 1 = columns
    side: str        # "lo" | "hi" — sender's edge
    depth: int       # k_ici * r rows/cols
    nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class HaloRecv:
    """Attach a neighbour's halo payload at this rank's ``side`` edge.

    ``src == -1`` marks a mesh edge: the band is zero-padded instead
    (exactly what ``ppermute`` leaves for non-receivers) and no ICI
    traffic occurs (``nbytes == 0``).  Every real recv (``src >= 0``)
    pairs 1:1 with a :class:`HaloSend` in the source rank's stream."""

    rank: int        # dst shard (owner of this stream)
    src: int         # src shard; -1 = mesh edge (zero fill)
    axis: int
    side: str        # "lo" | "hi" — receiver's edge
    depth: int
    nbytes: int      # 0 when src == -1
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class ShardKernel:
    """``steps`` fused, globally-masked stencil steps on the extended
    band, cropped back to the owned region.

    The band covers ``[gy0, gy0+h) x [gx0, gx0+w)`` in global
    coordinates (origin = owned region minus the ``k_ici*r`` halo).
    ``elements`` counts every updated element per round — the owned
    interior *plus* the redundant ghost wedges; ``hbm_bytes`` is one
    band read + one band write per fused call, mirroring
    :func:`fused_box_geometry`'s model."""

    rank: int
    stencil: str
    steps: int
    gy0: int
    gx0: int
    h: int
    w: int
    hbm_bytes: int
    flops: int
    elements: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class _HaloCodecOp:
    """Shared shape of the encode/decode halves of a compressed halo.

    The collective analogue of :class:`_CodecOp`: both halves carry the
    codec id, the raw and modeled-wire byte counts, and the wrapped
    ``HaloSend``/``HaloRecv``'s edge provenance, so
    :func:`repro.core.compress.compress_plan` builds one metadata dict
    per exchange and instantiates the pair from it.  ``wire_nbytes`` is
    the codec's deterministic analytic model — ICI accounting stays a
    property of the plan."""

    codec: str
    rank: int        # owner of the stream this op lives in
    peer: int        # the other end of the exchange (dst for send side)
    axis: int
    side: str        # the wrapped op's edge
    direction: str   # "send" | "recv"
    raw_nbytes: int
    wire_nbytes: int
    round: int
    phase: int


@dataclasses.dataclass(frozen=True)
class HaloCompress(_HaloCodecOp):
    """Encode a halo payload before it crosses the ICI link.

    Emitted immediately *before* the ``HaloSend`` it wraps; the wire
    then carries ``wire_nbytes`` instead of ``raw_nbytes``."""


@dataclasses.dataclass(frozen=True)
class HaloDecompress(_HaloCodecOp):
    """Decode a received halo payload on the far side of the ICI link.

    Emitted immediately *after* the real ``HaloRecv`` it wraps (edge
    recvs — ``src == -1`` zero fills — are never wrapped)."""


ShardOp = Union[ShardLoad, ShardStore, HaloSend, HaloRecv, ShardKernel,
                HaloCompress, HaloDecompress]


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """A compiled multi-device schedule: one op stream per shard.

    ``barriers`` is the global barrier structure: a tuple of phase
    labels; every op's ``phase`` indexes into it, and an executor must
    run phase ``p`` of *every* stream before any op of phase ``p+1``
    (within a phase, rank order is free — sends and recvs live in
    separate phases, so the lockstep is deadlock-free by construction).
    """

    stencil: str
    Y: int
    X: int
    itemsize: int
    n: int
    k_ici: int
    mesh_shape: Tuple[int, int]
    radius: int
    shards: Tuple[DeviceShard, ...]
    streams: Tuple[Tuple[ShardOp, ...], ...]
    barriers: Tuple[str, ...]
    exact_elements: int
    codec: str = ""     # "" = uncompressed halos; else the halo codec name
    trailing: Tuple[int, ...] = ()  # unsharded trailing axes (modeled only)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.Y, self.X)

    @property
    def n_ranks(self) -> int:
        return len(self.shards)

    @property
    def rounds(self) -> int:
        return self.n // self.k_ici

    def __len__(self) -> int:
        return sum(len(s) for s in self.streams)

    def _accumulate(self, s: "TransferStats", ops) -> "TransferStats":
        for op in ops:
            if isinstance(op, ShardLoad):
                s.h2d_bytes += op.nbytes
                s.h2d_wire_bytes += op.nbytes
            elif isinstance(op, ShardStore):
                s.d2h_bytes += op.nbytes
                s.d2h_wire_bytes += op.nbytes
            elif isinstance(op, HaloSend):
                s.ici_bytes += op.nbytes
                s.ici_wire_bytes += op.nbytes
                s.halo_ops += 1
            elif isinstance(op, HaloRecv):
                if op.src >= 0:
                    s.halo_ops += 1
            elif isinstance(op, HaloCompress):
                # the wrapped send contributed raw bytes to the wire
                # accumulator above; the codec swaps them for wire bytes
                s.codec_ops += 1
                s.ici_wire_bytes += op.wire_nbytes - op.raw_nbytes
            elif isinstance(op, HaloDecompress):
                s.codec_ops += 1
            elif isinstance(op, ShardKernel):
                s.kernel_calls += 1
                s.kernel_hbm_bytes += op.hbm_bytes
                s.flops += op.flops
                s.elements_computed += op.elements
            else:  # pragma: no cover - planner/IR version skew
                raise TypeError(f"unknown sharded op {op!r}")
        return s

    def stats(self) -> TransferStats:
        """Aggregate :class:`TransferStats` over every rank's stream —
        the single source of truth for the sharded accounting, derived
        from the plan with zero device work (the dry-run executor
        returns it untouched)."""
        s = TransferStats(exact_elements=self.exact_elements)
        for stream in self.streams:
            self._accumulate(s, stream)
        return s

    def per_rank_stats(self, rank: int) -> TransferStats:
        """One rank's accounting; ``exact_elements`` is the rank's share
        (``n x`` its owned-interior elements)."""
        sh = self.shards[rank]
        r = self.radius
        rows = max(0, min(sh.y1, self.Y - r) - max(sh.y0, r))
        cols = max(0, min(sh.x1, self.X - r) - max(sh.x0, r))
        s = TransferStats(exact_elements=self.n * rows * cols)
        return self._accumulate(s, self.streams[rank])

    def ici_bytes_per_round(self, rank: int) -> int:
        """Plan-derived send-side ICI bytes one rank pushes per round
        (uniform across rounds — round 0 is read off the stream)."""
        return sum(op.nbytes for op in self.streams[rank]
                   if isinstance(op, HaloSend) and op.round == 0)

    @property
    def collective_bytes_per_round(self) -> int:
        """Per-rank ICI bytes per round, derived from the op streams
        (max over ranks).  For a rank with neighbours on both sides of
        both mesh axes this equals the analytic formula in
        :func:`repro.core.distributed.collective_bytes_per_round`; edge
        ranks push less (no payload crosses a mesh boundary)."""
        return max((self.ici_bytes_per_round(r) for r in range(self.n_ranks)),
                   default=0)

    def ici_wire_bytes_per_round(self, rank: int) -> int:
        """Round-0 *wire* bytes one rank pushes: raw send payloads plus
        any halo-codec wire-vs-raw adjustments (equal to
        :meth:`ici_bytes_per_round` on uncompressed plans)."""
        total = 0
        for op in self.streams[rank]:
            if op.round != 0:
                continue
            if isinstance(op, HaloSend):
                total += op.nbytes
            elif isinstance(op, HaloCompress):
                total += op.wire_nbytes - op.raw_nbytes
        return total

    @property
    def collective_wire_bytes_per_round(self) -> int:
        """Wire-byte counterpart of :attr:`collective_bytes_per_round` —
        what the autotuner charges against ``bw_ici`` once halos are
        routed through a codec."""
        return max((self.ici_wire_bytes_per_round(r)
                    for r in range(self.n_ranks)), default=0)

    def breakdown(self) -> Dict[str, int]:
        """Per-category byte totals — the Fig. 7 bars plus the L2 ICI
        category (same keys as :meth:`ExecutionPlan.breakdown`)."""
        return self.stats().breakdown()

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for stream in self.streams:
            for op in stream:
                k = type(op).__name__
                out[k] = out.get(k, 0) + 1
        return out

    def phases(self) -> List[Tuple[str, List[ShardOp]]]:
        """Ops grouped by global phase, in barrier order (rank order
        within a phase) — the structure executors walk."""
        out: List[Tuple[str, List[ShardOp]]] = [
            (label, []) for label in self.barriers]
        for stream in self.streams:
            for op in stream:
                out[op.phase][1].append(op)
        return out


def fused_box_geometry(
    radius: int, flops_per_elem: int, shape: Sequence[int], steps: int,
    keep_lo: Sequence[bool], keep_hi: Sequence[bool], itemsize: int,
) -> Tuple[Tuple[int, ...], int, int, int]:
    """Accounting for one fused kernel call on an N-D band.

    Returns ``(shape_out, hbm_bytes, flops, elements)``: per step the
    compute volume is the band interior (every axis loses ``r`` per
    side), and each axis whose side is a domain frame (``keep_*``) gets
    its ``r`` frame slices passed through, so kept axes hold their
    extent while free sides shrink by ``r`` per step.  HBM traffic is
    one read of the input band plus one write of the output band."""
    r = radius
    cur = list(shape)
    vol_in = math.prod(cur)
    flops = 0
    elements = 0
    for _ in range(steps):
        interior = [c - 2 * r for c in cur]
        e = math.prod(interior)
        elements += e
        flops += e * flops_per_elem
        cur = [c - 2 * r + (int(kl) + int(kh)) * r
               for c, kl, kh in zip(cur, keep_lo, keep_hi)]
    hbm_bytes = (vol_in + math.prod(cur)) * itemsize
    return tuple(cur), hbm_bytes, flops, elements


def fused_kernel_geometry(
    radius: int, flops_per_elem: int, h: int, X: int, steps: int,
    keep_top: bool, keep_bottom: bool, itemsize: int,
) -> Tuple[int, int, int, int]:
    """Row-band special case of :func:`fused_box_geometry` (kept for the
    pre-box callers): returns ``(h_out, hbm_bytes, flops, elements)``."""
    shape_out, hbm, flops, elems = fused_box_geometry(
        radius, flops_per_elem, (h, X), steps,
        (keep_top, True), (keep_bottom, True), itemsize)
    return shape_out[0], hbm, flops, elems


class PlanBuilder:
    """Validating builder the engine planners drive.

    Tracks every live register/buffer's *global* box (absolute framed-
    domain coordinates) so emitted byte counts and geometry are
    consistent; catches planner bugs (reading an unwritten buffer,
    double-reading a carry, kernel on a dead register, non-adjacent
    concatenation, D2H of rows the register does not hold) at compile
    time instead of at execution time.

    The scalar methods (:meth:`h2d`, :meth:`buffer_write`, ...) address
    ``[lo, hi)`` intervals along ``chunk_axis`` with full extent on every
    other axis — the 1-axis streaming idiom of the classic engines, valid
    for any ``chunk_axis`` of any N-D domain.  The ``*_box`` variants
    take explicit boxes (the multi-axis temporal-blocking planner)."""

    def __init__(self, engine: str, stencil, shape: Sequence[int], n: int,
                 d: int, k_off: int, k_on: int, itemsize: int,
                 chunk_axis: int = 0, tiles: Sequence[int] = ()):
        self.engine = engine
        self.st = stencil
        self.shape = tuple(shape)
        if not 0 <= chunk_axis < len(self.shape):
            raise ValueError(
                f"chunk_axis {chunk_axis} out of range for shape {self.shape}")
        self.axis = chunk_axis
        self.tiles = tuple(tiles)
        self.n, self.d, self.k_off, self.k_on = n, d, k_off, k_on
        self.itemsize = itemsize
        self.domain = Box.from_shape(self.shape)
        self.ops: List[Op] = []
        self._reg_box: Dict[str, Box] = {}    # live register -> global box
        self._buf_box: Dict[str, Box] = {}    # unread buffer -> global box
        self._staged_bytes = 0
        self._codec = None                    # set by with_compression()

    def with_compression(self, codec) -> "PlanBuilder":
        """Attach a transfer codec (name or :class:`~repro.core.compress.Codec`).

        Chainable; :meth:`build` then rewrites the finished schedule with
        :func:`repro.core.compress.compress_plan`, wrapping every
        ``H2D``/``D2H`` in a ``Compress``/``Decompress`` pair.  Planners
        stay codec-oblivious: the same engine code emits compressed and
        uncompressed schedules."""
        self._codec = codec
        return self

    def _bytes(self, box: Box) -> int:
        return box.volume * self.itemsize

    def _span(self, lo: int, hi: int) -> Box:
        return Box.span(self.shape, self.axis, lo, hi)

    def height(self, reg: str) -> int:
        """Current extent of a live register along the chunk axis
        (planners use it to address slices relative to the evolving
        band)."""
        return self._reg_box[reg].extent(self.axis)

    # -- box-native ops ------------------------------------------------

    def h2d_box(self, reg: str, box: Box, rnd: int, chunk: int) -> None:
        assert self.domain.contains(box), (box, self.shape)
        assert box.volume > 0, f"empty H2D box {box}"
        assert reg not in self._reg_box, f"register {reg!r} already live"
        self._reg_box[reg] = box
        self.ops.append(H2D(reg, box, self._bytes(box), rnd, chunk))

    def fused_kernel_box(self, reg: str, steps: int,
                         keep_lo: Sequence[bool], keep_hi: Sequence[bool],
                         rnd: int, chunk: int) -> None:
        box = self._reg_box[reg]
        shape_out, hbm, flops, elems = fused_box_geometry(
            self.st.radius, self.st.flops_per_elem, box.shape, steps,
            keep_lo, keep_hi, self.itemsize)
        assert all(s > 0 for s in shape_out), \
            f"register {reg!r} shrinks to {shape_out} after {steps} steps"
        shrink = steps * self.st.radius
        self._reg_box[reg] = box.shrink(
            [0 if kl else shrink for kl in keep_lo],
            [0 if kh else shrink for kh in keep_hi])
        self.ops.append(FusedKernel(
            reg, self.st.name, steps, tuple(bool(k) for k in keep_lo),
            tuple(bool(k) for k in keep_hi), box.shape, shape_out,
            hbm, flops, elems, rnd, chunk))

    def d2h_box(self, reg: str, host_box: Box, rnd: int, chunk: int) -> None:
        """Stage the register slices covering ``host_box`` (absolute
        coordinates) back to the host."""
        box = self._reg_box.pop(reg)      # last use: the register dies here
        assert box.contains(host_box), (box, host_box)
        reg_box = host_box.translate([-l for l in box.lo])
        nbytes = self._bytes(host_box)
        self._staged_bytes += nbytes
        self.ops.append(D2H(reg, reg_box, host_box, nbytes, rnd, chunk))

    # -- 1-axis convenience ops (the classic engine idiom) -------------

    def h2d(self, reg: str, lo: int, hi: int, rnd: int, chunk: int) -> None:
        L = self.shape[self.axis]
        assert 0 <= lo < hi <= L, (lo, hi)
        self.h2d_box(reg, self._span(lo, hi), rnd, chunk)

    def buffer_write(self, buf: str, reg: str, reg_lo: int, reg_hi: int,
                     rnd: int, chunk: int) -> None:
        box = self._reg_box[reg]
        h = box.extent(self.axis)
        assert 0 <= reg_lo < reg_hi <= h, (reg_lo, reg_hi, h)
        assert buf not in self._buf_box, f"buffer {buf!r} written twice"
        base = box.lo[self.axis]
        self._buf_box[buf] = box.with_axis(
            self.axis, base + reg_lo, base + reg_hi)
        rel = Box.span(box.shape, self.axis, reg_lo, reg_hi)
        self.ops.append(BufferWrite(buf, reg, rel, self._bytes(rel),
                                    rnd, chunk))

    def buffer_read(self, reg: str, buf: str, src: str, rnd: int,
                    chunk: int) -> None:
        bbox = self._buf_box.pop(buf)   # each shared region is consumed once
        sbox = self._reg_box.pop(src)
        assert bbox.hi[self.axis] == sbox.lo[self.axis], \
            f"buffer {buf!r} {bbox} not adjacent to register {src!r} {sbox}"
        self._reg_box[reg] = sbox.with_axis(
            self.axis, bbox.lo[self.axis], sbox.hi[self.axis])
        self.ops.append(BufferRead(reg, buf, src, self._bytes(bbox),
                                   self.axis, bbox.extent(self.axis),
                                   rnd, chunk))

    def fused_kernel(self, reg: str, steps: int, keep_top: bool,
                     keep_bottom: bool, rnd: int, chunk: int) -> None:
        nd = len(self.shape)
        keep_lo = [True] * nd
        keep_hi = [True] * nd
        keep_lo[self.axis] = bool(keep_top)
        keep_hi[self.axis] = bool(keep_bottom)
        self.fused_kernel_box(reg, steps, keep_lo, keep_hi, rnd, chunk)

    def d2h(self, reg: str, reg_lo: int, reg_hi: int, host_lo: int,
            host_hi: int, rnd: int, chunk: int) -> None:
        box = self._reg_box[reg]
        h = box.extent(self.axis)
        assert 0 <= reg_lo < reg_hi <= h, (reg_lo, reg_hi, h)
        assert reg_hi - reg_lo == host_hi - host_lo
        assert box.lo[self.axis] + reg_lo == host_lo, \
            f"register {reg!r} {box} does not hold host rows " \
            f"[{host_lo}, {host_hi}) at [{reg_lo}, {reg_hi})"
        self.d2h_box(reg, self._span(host_lo, host_hi), rnd, chunk)

    def commit(self, rnd: int) -> None:
        self.ops.append(HostCommit(self._staged_bytes, rnd))
        self._staged_bytes = 0

    def build(self) -> ExecutionPlan:
        assert not self._reg_box, f"leaked registers: {sorted(self._reg_box)}"
        assert not self._buf_box, f"unread buffers: {sorted(self._buf_box)}"
        assert self._staged_bytes == 0, "uncommitted D2H boxes at end of plan"
        r = self.st.radius
        exact = self.n * math.prod(s - 2 * r for s in self.shape)
        plan = ExecutionPlan(
            engine=self.engine, stencil=self.st.name, shape=self.shape,
            itemsize=self.itemsize, n=self.n, d=self.d, k_off=self.k_off,
            k_on=self.k_on, exact_elements=exact, ops=tuple(self.ops),
            chunk_axis=self.axis, tiles=self.tiles,
        )
        if self._codec is not None:
            from .compress import compress_plan   # local: avoids import cycle
            plan = compress_plan(plan, self._codec)
        return plan
