"""Hierarchical plans: nested out-of-core streaming *inside* shards.

SO2DR's core trade — share overlap regions off-chip, tolerate redundant
compute to unlock reuse — applies recursively at every level of the
memory hierarchy.  :mod:`repro.core.shard` compiles the L2 (inter-chip)
schedule but assumes each shard's halo-extended band pair fits in device
memory (:func:`~repro.core.shard.shard_working_set` vs ``c_dev``).  This
module removes that assumption:

:func:`compile_hierarchical` compiles the outer :class:`ShardedPlan` as
usual, and when a shard's working set exceeds the device budget it
expands every :class:`~repro.core.plan.ShardKernel` into a nested L1
:class:`~repro.core.plan.ExecutionPlan` — any engine flavour:

* ``resreu``  — independent row chunks, full halo-extended ext per H2D
  (the result-reuse layout: redundant transfer, no carry);
* ``so2dr``   — row chunks sharing the ``2*k_ici*r`` overlap region
  through an on-device carry buffer (each band row crosses PCIe once);
* ``box_tb``  — a ``(ty, tx)`` tile grid over the owned region, each
  tile's ext extended by the halo depth on all four sides.

The inner plan streams the shard's band chunk-wise through the ordinary
H2D/D2H + FusedKernel vocabulary, so the existing lowering layer, slot
pool, codecs and executors all apply unchanged one level down.  Inner
kernels are *masked*: they run the same
:func:`repro.core.distributed.masked_local_steps` update as the outer
``ShardKernel`` (global-coordinate interior mask, band frame preserved),
so chunked execution is bit-identical to the flat band pass — only rows
and columns at halo depth from each ext edge are written back.

The result is a :class:`HierarchicalPlan`: the outer plan keeps its ICI
accounting (halo bytes, ghost wedges, optional halo codec from
:func:`repro.core.compress.compress_plan`) while the inner plans supply
the per-round H2D/D2H/buffer/kernel accounting, rolled up per shard x
round into one :class:`~repro.core.plan.TransferStats` —
``DryRunExecutor`` costs both levels with zero devices, and the
simulator returns the identical numbers by construction.

When every shard fits the budget (and no explicit ``inner_d``/
``inner_tiles`` forces a split), :func:`compile_hierarchical` returns
the flat :class:`ShardedPlan` untouched — expansion is a strict no-op,
pinned by ``tests/data/golden_sharded_plans.json``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from .compress import compress_plan
from .plan import (
    Box, ExecutionPlan, FusedKernel, HaloCompress, HaloDecompress, HaloRecv,
    HaloSend, PlanBuilder, ShardedPlan, TransferStats,
)
from .shard import _overlap, compile_sharded, shard_working_set
from .stencil import get_stencil

__all__ = ["HierarchicalPlan", "compile_hierarchical"]

INNER_ENGINES = ("so2dr", "resreu", "box_tb")


@dataclasses.dataclass(frozen=True)
class HierarchicalPlan:
    """A two-level schedule: an outer :class:`ShardedPlan` whose compute
    phases are realized by nested per-rank inner plans.

    ``inner[rank]`` is ONE round of rank ``rank``'s band update — the
    executors run it once per outer round (``outer.rounds`` times), with
    the rank's halo-extended band standing in as the inner plan's host
    domain.  Inner plans are per-rank because the masked element counts
    differ at the global domain edges even though the geometry is
    uniform.

    Accounting: ICI fields come from the outer streams (halo sends,
    recvs and any halo-codec ops); H2D/D2H/buffer/kernel fields come
    from the inner plans times ``outer.rounds``.  The outer
    ``ShardLoad``/``ShardStore`` ops are *excluded* — in the
    hierarchical regime the shard band is host-resident and the inner
    chunk H2D/D2H ops are the real interconnect traffic."""

    outer: ShardedPlan
    inner: Tuple[ExecutionPlan, ...]
    inner_engine: str
    c_dev: int = 0

    # -- geometry delegation (the outer plan carries it all) -----------

    @property
    def stencil(self) -> str:
        return self.outer.stencil

    @property
    def Y(self) -> int:
        return self.outer.Y

    @property
    def X(self) -> int:
        return self.outer.X

    @property
    def shape(self) -> Tuple[int, int]:
        return self.outer.shape

    @property
    def itemsize(self) -> int:
        return self.outer.itemsize

    @property
    def n(self) -> int:
        return self.outer.n

    @property
    def k_ici(self) -> int:
        return self.outer.k_ici

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return self.outer.mesh_shape

    @property
    def radius(self) -> int:
        return self.outer.radius

    @property
    def shards(self):
        return self.outer.shards

    @property
    def barriers(self):
        return self.outer.barriers

    @property
    def n_ranks(self) -> int:
        return self.outer.n_ranks

    @property
    def rounds(self) -> int:
        return self.outer.rounds

    @property
    def exact_elements(self) -> int:
        return self.outer.exact_elements

    @property
    def codec(self) -> str:
        """The outer halo codec ("" = uncompressed halos)."""
        return self.outer.codec

    @property
    def trailing(self) -> Tuple[int, ...]:
        return self.outer.trailing

    @property
    def inner_chunks(self) -> int:
        """Chunks per inner round (``d`` of the nested plans)."""
        return self.inner[0].d if self.inner else 0

    def __len__(self) -> int:
        return len(self.outer) + self.rounds * sum(
            len(p) for p in self.inner)

    # -- accounting ----------------------------------------------------

    def _accumulate_outer(self, s: TransferStats, stream) -> None:
        """The outer stream's ICI share: halo sends/recvs plus halo-codec
        wire adjustments.  ShardLoad/ShardStore and ShardKernel are
        skipped — the inner plans account for the band traffic and the
        (chunked, masked) compute."""
        for op in stream:
            if isinstance(op, HaloSend):
                s.ici_bytes += op.nbytes
                s.ici_wire_bytes += op.nbytes
                s.halo_ops += 1
            elif isinstance(op, HaloRecv):
                if op.src >= 0:
                    s.halo_ops += 1
            elif isinstance(op, HaloCompress):
                s.codec_ops += 1
                s.ici_wire_bytes += op.wire_nbytes - op.raw_nbytes
            elif isinstance(op, HaloDecompress):
                s.codec_ops += 1

    def _accumulate_inner(self, s: TransferStats, rank: int) -> None:
        ist = self.inner[rank].stats()
        R = self.rounds
        s.h2d_bytes += R * ist.h2d_bytes
        s.h2d_wire_bytes += R * ist.h2d_wire_bytes
        s.d2h_bytes += R * ist.d2h_bytes
        s.d2h_wire_bytes += R * ist.d2h_wire_bytes
        s.codec_ops += R * ist.codec_ops
        s.buffer_bytes += R * ist.buffer_bytes
        s.kernel_calls += R * ist.kernel_calls
        s.kernel_hbm_bytes += R * ist.kernel_hbm_bytes
        s.flops += R * ist.flops
        s.elements_computed += R * ist.elements_computed

    def stats(self) -> TransferStats:
        """Both levels rolled into one :class:`TransferStats` — the
        single source of truth, derived from the plans with zero device
        work (``DryRunExecutor`` returns it untouched, the simulator
        returns it alongside the computed domain)."""
        s = TransferStats(exact_elements=self.exact_elements)
        for rank in range(self.n_ranks):
            self._accumulate_outer(s, self.outer.streams[rank])
            self._accumulate_inner(s, rank)
        return s

    def per_rank_stats(self, rank: int) -> TransferStats:
        """One shard's roll-up: its outer ICI share plus its inner plan
        times ``rounds``; ``exact_elements`` is the rank's owned-interior
        share."""
        sh = self.shards[rank]
        r = self.radius
        rows = max(0, min(sh.y1, self.Y - r) - max(sh.y0, r))
        cols = max(0, min(sh.x1, self.X - r) - max(sh.x0, r))
        s = TransferStats(exact_elements=self.n * rows * cols)
        self._accumulate_outer(s, self.outer.streams[rank])
        self._accumulate_inner(s, rank)
        return s

    def inner_stats(self, rank: int) -> TransferStats:
        """One round of one rank's nested plan, un-multiplied — the L1
        accounting a per-chunk property test reads."""
        return self.inner[rank].stats()

    def ici_bytes_per_round(self, rank: int) -> int:
        return self.outer.ici_bytes_per_round(rank)

    def ici_wire_bytes_per_round(self, rank: int) -> int:
        return self.outer.ici_wire_bytes_per_round(rank)

    @property
    def collective_bytes_per_round(self) -> int:
        return self.outer.collective_bytes_per_round

    @property
    def collective_wire_bytes_per_round(self) -> int:
        return self.outer.collective_wire_bytes_per_round

    def breakdown(self) -> Dict[str, int]:
        return self.stats().breakdown()

    def op_counts(self) -> Dict[str, int]:
        """Outer op counts plus inner op counts times ``rounds`` (the
        ops an executor actually issues)."""
        out = self.outer.op_counts()
        for p in self.inner:
            for k, v in p.op_counts().items():
                out[k] = out.get(k, 0) + self.rounds * v
        return out


def _chunk_bounds(extent: int, parts: int, base: int) -> Tuple[Tuple[int, int], ...]:
    """Partition ``[base, base + extent)`` into ``parts`` near-equal
    spans (earlier spans take the remainder, every span non-empty)."""
    size, rem = divmod(extent, parts)
    bounds = []
    a = base
    for i in range(parts):
        b = a + size + (1 if i < rem else 0)
        bounds.append((a, b))
        a = b
    return tuple(bounds)


def _masked_kernel(b: PlanBuilder, reg: str, chunk: int, st, steps: int,
                   gy0: int, gx0: int, Y: int, X: int,
                   t_interior: int) -> None:
    """Append a *masked* FusedKernel on ``reg``'s current ext box.

    Masked semantics (the ShardKernel update, one level down): every
    step writes the ext centre wherever the global-coordinate interior
    mask holds, and the band frame is preserved — so the ext box does
    not shrink (all keeps set) and the element count is the global
    interior overlap of the inset ext, per step.  The builder's
    geometry helper cannot express that, hence the manual append; the
    ext box is untouched because every side is kept."""
    r = st.radius
    ext = b._reg_box[reg]
    rows = _overlap(gy0 + ext.lo[0] + r, gy0 + ext.hi[0] - r, r, Y - r)
    cols = _overlap(gx0 + ext.lo[1] + r, gx0 + ext.hi[1] - r, r, X - r)
    elements = steps * rows * cols * t_interior
    b.ops.append(FusedKernel(
        reg, st.name, steps, (True, True), (True, True),
        ext.shape, ext.shape, 2 * ext.volume * b.itemsize,
        elements * st.flops_per_elem, elements, 0, chunk))


def _build_row_inner(engine: str, st, h: int, w: int, ly: int, hk: int,
                     d: int, k: int, gy0: int, gx0: int, Y: int, X: int,
                     itemsize_eff: int, t_interior: int,
                     inner_codec) -> ExecutionPlan:
    """One round of one rank's band update as a row-chunked inner plan.

    ``resreu`` loads each chunk's full halo-extended ext (aprons cross
    the wire twice per interior boundary); ``so2dr`` carries the
    ``2*hk`` overlap rows on-device in a shared buffer, so each band row
    is loaded exactly once per round."""
    b = PlanBuilder(f"hier-{engine}", st, (h, w), n=k, d=d,
                    k_off=k, k_on=k, itemsize=itemsize_eff)
    if inner_codec is not None:
        b.with_compression(inner_codec)
    chunks = _chunk_bounds(ly, d, hk)   # owned rows, band coordinates
    prev_b = 0
    for i, (a, bb) in enumerate(chunks):
        if engine == "resreu" or i == 0:
            reg = f"band:r0c{i}"
            b.h2d(reg, a - hk, bb + hk, 0, i)
        else:
            # so2dr: only the fresh rows cross the wire; the 2*hk apron
            # arrives through the carry buffer written by chunk i-1
            src = f"src:r0c{i}"
            b.h2d(src, prev_b + hk, bb + hk, 0, i)
            reg = f"band:r0c{i}"
            b.buffer_read(reg, f"carry:c{i - 1}", src, 0, i)
        if engine == "so2dr" and i < d - 1:
            # bottom 2*hk INPUT rows, captured before the kernel runs
            ext_h = b.height(reg)
            b.buffer_write(f"carry:c{i}", reg, ext_h - 2 * hk, ext_h, 0, i)
        _masked_kernel(b, reg, i, st, k, gy0, gx0, Y, X, t_interior)
        b.d2h_box(reg, Box((a, hk), (bb, w - hk)), 0, i)
        prev_b = bb
    b.commit(0)
    # n*(shape-2r) is meaningless for one masked round of a band slice;
    # exact/redundant accounting lives on the HierarchicalPlan
    return dataclasses.replace(b.build(), exact_elements=0)


def _build_box_inner(st, h: int, w: int, ly: int, lx: int, hk: int,
                     tiles: Tuple[int, int], k: int, gy0: int, gx0: int,
                     Y: int, X: int, itemsize_eff: int, t_interior: int,
                     inner_codec) -> ExecutionPlan:
    """One round of one rank's band update as a ``(ty, tx)`` tile grid:
    each tile's ext extends ``hk`` on all four sides (never clipped —
    the band frame is exactly the halo depth)."""
    ty, tx = tiles
    b = PlanBuilder("hier-box_tb", st, (h, w), n=k, d=ty * tx,
                    k_off=k, k_on=k, itemsize=itemsize_eff, tiles=tiles)
    if inner_codec is not None:
        b.with_compression(inner_codec)
    ci = 0
    for a, bb in _chunk_bounds(ly, ty, hk):
        for cc, ee in _chunk_bounds(lx, tx, hk):
            reg = f"tile:r0c{ci}"
            b.h2d_box(reg, Box((a - hk, cc - hk), (bb + hk, ee + hk)), 0, ci)
            _masked_kernel(b, reg, ci, st, k, gy0, gx0, Y, X, t_interior)
            b.d2h_box(reg, Box((a, cc), (bb, ee)), 0, ci)
            ci += 1
    b.commit(0)
    return dataclasses.replace(b.build(), exact_elements=0)


def _derive_row_chunks(ly: int, w: int, hk: int, itemsize_eff: int,
                       c_dev: int) -> int:
    """Smallest chunk count whose in/out ext pair fits ``c_dev``."""
    cap = c_dev // (2 * w * itemsize_eff) - 2 * hk
    if cap < 1:
        raise ValueError(
            f"c_dev={c_dev} cannot hold even a one-row chunk "
            f"(2*({1 + 2 * hk})*{w}*{itemsize_eff} bytes); no row-chunked "
            "inner schedule exists — shrink the halo depth k_ici or the "
            "shard width")
    return min(ly, -(-ly // cap))


def _derive_tiles(ly: int, lx: int, hk: int, itemsize_eff: int,
                  c_dev: int) -> Tuple[int, int]:
    """Smallest square-ish tile grid whose largest ext pair fits
    ``c_dev``."""
    for t in range(1, max(ly, lx) + 1):
        ty, tx = min(t, ly), min(t, lx)
        tile_y, tile_x = -(-ly // ty), -(-lx // tx)
        if 2 * (tile_y + 2 * hk) * (tile_x + 2 * hk) * itemsize_eff <= c_dev:
            return ty, tx
    raise ValueError(
        f"c_dev={c_dev} cannot hold even a one-point tile "
        f"(2*({1 + 2 * hk})^2*{itemsize_eff} bytes); no tiled inner "
        "schedule exists — shrink the halo depth k_ici")


def compile_hierarchical(stencil, Y: int, X: int, n: int, k_ici: int,
                         mesh_shape: Tuple[int, int],
                         itemsize: int = 4,
                         c_dev: Optional[int] = None,
                         hw=None,
                         inner_engine: str = "so2dr",
                         inner_d: Optional[int] = None,
                         inner_tiles: Optional[Tuple[int, int]] = None,
                         codec=None,
                         inner_codec=None,
                         trailing: Tuple[int, ...] = ()):
    """Compile the two-level schedule for ``(shape, stencil, budget)``.

    The outer :class:`ShardedPlan` is compiled exactly as
    :func:`repro.core.shard.compile_sharded` would (same streams, same
    barriers, same accounting).  Then:

    * if every shard's working-set pair fits ``c_dev`` (taken from
      ``hw.c_dev`` when only ``hw`` is given; ``None`` = unbounded) and
      no explicit ``inner_d``/``inner_tiles`` forces a split, the flat
      plan is returned **unchanged** — expansion is a strict no-op;
    * otherwise each rank's ``ShardKernel`` expands into a nested
      ``inner_engine`` plan (``so2dr`` | ``resreu`` | ``box_tb``) that
      streams the shard's band chunk-wise, and a
      :class:`HierarchicalPlan` is returned.

    ``codec`` routes the outer halo exchange through the codec registry
    (:func:`repro.core.compress.compress_plan` on the ShardedPlan);
    ``inner_codec`` compresses the nested H2D/D2H streams.  ``trailing``
    models unsharded trailing axes (dry-run only): the trailing volume
    folds into the inner plans' itemsize so byte accounting scales,
    while element counts scale by the trailing interior."""
    if inner_engine not in INNER_ENGINES:
        raise ValueError(
            f"unknown inner engine {inner_engine!r}; known: {INNER_ENGINES}")
    st = get_stencil(stencil) if isinstance(stencil, str) else stencil
    r = st.radius
    if c_dev is None and hw is not None:
        c_dev = hw.c_dev
    outer = compile_sharded(st, Y, X, n, k_ici, mesh_shape,
                            itemsize=itemsize, trailing=trailing)
    n_row, n_col = outer.mesh_shape
    ly, lx = Y // n_row, X // n_col
    hk = k_ici * r
    h, w = ly + 2 * hk, lx + 2 * hk

    ws = shard_working_set(ly, lx, hk, itemsize, trailing)
    explicit = inner_d is not None or inner_tiles is not None
    if (c_dev is None or ws <= c_dev) and not explicit:
        # fits: the expansion pass is a strict no-op (golden-pinned)
        return compress_plan(outer, codec) if codec is not None else outer

    if codec is not None:
        outer = compress_plan(outer, codec)
    if inner_codec is not None and trailing:
        raise ValueError(
            "inner_codec cannot combine with trailing axes: the trailing "
            "volume folds into the inner plans' itemsize, which the codec "
            "registry's itemsize constraints reject")

    t_mult = math.prod(trailing) if trailing else 1
    t_interior = math.prod(t - 2 * r for t in trailing) if trailing else 1
    itemsize_eff = itemsize * t_mult

    if inner_engine == "box_tb":
        if inner_tiles is not None:
            ty, tx = inner_tiles
            if not (1 <= ty <= ly and 1 <= tx <= lx):
                raise ValueError(
                    f"inner_tiles {inner_tiles} out of range for a "
                    f"({ly}, {lx}) shard")
            tiles = (ty, tx)
        else:
            tiles = _derive_tiles(ly, lx, hk, itemsize_eff, c_dev)
        build = lambda gy0, gx0: _build_box_inner(     # noqa: E731
            st, h, w, ly, lx, hk, tiles, k_ici, gy0, gx0, Y, X,
            itemsize_eff, t_interior, inner_codec)
    else:
        if inner_tiles is not None:
            raise ValueError(
                f"inner_tiles only applies to box_tb, not {inner_engine!r}")
        if inner_d is not None:
            if not 1 <= inner_d <= ly:
                raise ValueError(
                    f"inner_d={inner_d} out of range for {ly} owned rows")
            d = inner_d
        else:
            d = _derive_row_chunks(ly, w, hk, itemsize_eff, c_dev)
        build = lambda gy0, gx0: _build_row_inner(     # noqa: E731
            inner_engine, st, h, w, ly, hk, d, k_ici, gy0, gx0, Y, X,
            itemsize_eff, t_interior, inner_codec)

    inner = tuple(build(sh.y0 - hk, sh.x0 - hk) for sh in outer.shards)
    return HierarchicalPlan(outer=outer, inner=inner,
                            inner_engine=inner_engine, c_dev=c_dev or 0)
