"""Deterministic, host-sharded synthetic token pipeline.

Production properties that matter at 1000-node scale and are reproduced
here faithfully even though the corpus is synthetic:

* **statelessness** — batch ``i`` is a pure function of (seed, step,
  host_shard), so a restarted/elastic job resumes mid-epoch with no data
  loss or duplication (the checkpoint only stores the step);
* **host sharding** — each host materializes only its slice of the global
  batch (``host_slice``), matching multi-host jax.Array construction;
* **prefetch** — a background thread keeps ``prefetch`` batches ready so
  host-side generation overlaps device compute.

The token stream is a mixture of Zipf-distributed unigrams and a
repetition process, giving a learnable (compressible) distribution so
training-loss decrease is a meaningful test signal.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataSpec", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class DataSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Stateless synthetic LM data: batch(step) -> {tokens, labels}."""

    def __init__(self, spec: DataSpec, prefetch: int = 2):
        self.spec = spec
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        ranks = np.arange(1, spec.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, step: int) -> dict:
        spec = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([spec.seed, step, spec.host_id])
        )
        B, S = spec.host_batch, spec.seq_len
        toks = rng.choice(spec.vocab, size=(B, S + 1), p=self._p)
        # repetition process: with p=0.3, copy the token 4 back (learnable)
        rep = rng.random((B, S + 1)) < 0.3
        for off in (4,):
            idx = np.arange(S + 1)
            src = np.clip(idx - off, 0, None)
            toks = np.where(rep, toks[:, src], toks)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ---- prefetching iterator -------------------------------------------
    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                self._q.put(self.batch(step))
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            stop.set()
