from .pipeline import SyntheticLM, DataSpec  # noqa: F401
