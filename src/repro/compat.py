"""JAX version compatibility layer.

The repo targets the newest JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``), but
deployment containers pin older releases (0.4.x) where those names either
live under ``jax.experimental`` or do not exist.  Everything that touches
meshes or shard_map goes through this module so the drift is absorbed in
exactly one place.
"""
from __future__ import annotations

import enum
import inspect
from typing import Optional, Sequence

import jax

__all__ = ["AxisType", "make_mesh", "shard_map"]


class _AxisTypeFallback(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on JAX < 0.6."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeFallback)

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    axis_types: Optional[Sequence] = None,
    **kwargs,
):
    """``jax.make_mesh`` accepting ``axis_types`` on every JAX version.

    Older JAX has no ``axis_types`` parameter and treats every axis as
    Auto — which is the only mode this repo uses — so the argument is
    dropped when unsupported (support is probed once from the signature,
    never by swallowing the call's own TypeErrors).
    """
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, **kwargs)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "check_vma"
    from jax.experimental.shard_map import shard_map as fn  # JAX <= 0.4.x
    return fn, "check_rep"


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check flag spelled portably
    (``check_vma`` on new JAX, ``check_rep`` before the rename)."""
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
